//! The engine thread: pooled, batch-dispatched model execution behind a
//! channel, over any [`crate::backend::Backend`].
//!
//! The engine owns one backend (constructed *on* the engine thread from
//! a [`BackendFactory`] — PJRT handles are not `Send`) plus a
//! [`SessionPool`]: a bounded, LRU-evicted slab of open
//! [`InferenceSession`]s, so **several stage-1 sessions stay alive per
//! backend** and escalations target them by id.  The job loop drains
//! whatever is queued into one dispatch window per wakeup; compatible
//! `Refine` jobs (same target plan, fire-and-forget) are handed to
//! [`crate::backend::Backend::merge_sessions`] and, when the backend
//! supports it, escalate as **one merged dispatch** — restoring
//! cross-batch coalescing of stateless escalation groups, and cutting
//! per-job round-trips for stateful backends.  Merged outputs are split
//! back per caller from the session's `part_rows`/`part_steps`, so each
//! job still receives exactly the logits and charges its serial dispatch
//! would have produced (bit-identity is the backends' merge contract).
//!
//! Failures are kept twofold: each job's error is returned to its
//! caller, *and* recent backend failures are recorded in a bounded
//! [`ErrorRing`] so a later `submit` against a dead engine reports the
//! root cause and a cascade stays diagnosable post-mortem
//! ([`Engine::recent_errors`]).  Backend calls run under a panic guard
//! ([`no_unwind`]): a panicking backend op becomes a named, transient
//! error for that one job instead of killing the engine thread.
//! Closed and evicted session ids are never reused, and a `Refine`
//! against one names what happened to it.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::backend::{Backend, BackendFactory, InferenceSession, KernelPath, MergeOutcome, StepReport};
use crate::coordinator::metrics::ErrorRing;
use crate::coordinator::overload::{bounded_queue, QueueSendError, QueueTx, OVERLOADED};
use crate::precision::PrecisionPlan;
use crate::runtime::Execution;
use crate::sim::tensor::Tensor;

/// Run a backend call under a panic guard: an unwinding backend op is
/// converted into a named error (marked `(transient)` — a retry against
/// a fresh or resurrected session may well succeed) so one poisoned op
/// cannot take down the engine thread and every other pooled session
/// with it.
fn no_unwind<T>(what: &str, f: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(anyhow!("backend panicked during {what}: {msg} (transient)"))
        }
    }
}

/// Engine-thread-local session handle.
pub type SessionId = u64;

/// Most jobs drained into one dispatch window.
const MAX_DRAIN: usize = 64;

/// Engine tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Most sessions kept resident in the pool; beyond it the least
    /// recently used session is evicted (its id is retired with the
    /// eviction reason).
    pub pool_cap: usize,
    /// Admission bound of the engine's job queue: work jobs beyond this
    /// depth are refused with a named `(overloaded)` error at `submit`
    /// (control jobs — `Close`, pin/unpin — always land, or a refused
    /// cleanup would leak pool slots).
    pub queue_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { pool_cap: 32, queue_cap: 512 }
    }
}

/// Live counters of the pool and the merge path, shared with the engine
/// handle (and surfaced by `coordinator::Metrics`).
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Sessions currently resident in the pool.
    pub sessions_open: AtomicU64,
    /// High-water mark of resident sessions.
    pub sessions_peak: AtomicU64,
    /// Sessions evicted by the LRU bound.
    pub evictions: AtomicU64,
    /// Merged dispatches performed (≥ 2 refine jobs fused into one).
    pub merges: AtomicU64,
    /// Backend dispatches saved by merging — Σ (parts − 1) over merged
    /// dispatches (for the stateless PJRT backend with shared seeds this
    /// is padded artifact runs saved).
    pub runs_saved: AtomicU64,
    /// Streaming frames served (`SubmitFrame` rebases that completed).
    pub stream_frames: AtomicU64,
    /// Input-frame elements observed unchanged across rebases —
    /// accumulated by the stream registry from its per-frame diffs, a
    /// proxy for the accumulator rows the backend reused.
    pub stream_rows_reused: AtomicU64,
    /// Σ per-frame changed fraction in milli-units (0–1000); the mean
    /// rebase fraction is `stream_frac_milli / stream_frames`.
    pub stream_frac_milli: AtomicU64,
    /// New sessions bounced by a fully *pinned* pool — a capacity
    /// refusal (named `(overloaded)`), distinct from LRU `evictions`.
    pub pool_bounces: AtomicU64,
    /// Outputs served through the IntKernel's scalar contraction.
    pub kernel_scalar: AtomicU64,
    /// Outputs served through the word-at-a-time packed contraction.
    pub kernel_packed: AtomicU64,
    /// Outputs served through the multi-word blocked contraction.
    pub kernel_blocked: AtomicU64,
    /// Outputs whose pass took the im2col-free direct convolution walk
    /// for at least one layer.
    pub kernel_direct: AtomicU64,
}

impl EngineStats {
    pub fn sessions_open(&self) -> u64 {
        self.sessions_open.load(Ordering::Relaxed)
    }

    /// Record which contraction path served one output.  Backends that
    /// do not tag their passes (`KernelPath::Other`: the exact sim, the
    /// PJRT artifacts) are the untagged remainder of `completed`.
    fn note_kernel_path(&self, path: KernelPath) {
        let counter = match path {
            KernelPath::Other => return,
            KernelPath::Scalar => &self.kernel_scalar,
            KernelPath::Packed => &self.kernel_packed,
            KernelPath::Blocked => &self.kernel_blocked,
            KernelPath::Direct => &self.kernel_direct,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// A unit of engine work.
pub enum EngineJob {
    /// Open a session at `plan` and run it over one padded batch.
    /// `keep` leaves the session open in the pool (returning its id) so
    /// the caller can `Refine` it later; otherwise it closes after the
    /// pass.
    Begin {
        plan: PrecisionPlan,
        /// Row-major `[batch, H, W, C]` input.
        x: Vec<f32>,
        batch: usize,
        seed: u64,
        keep: bool,
        reply: mpsc::SyncSender<Result<EngineOutput>>,
    },
    /// Escalate a pooled session: optionally narrow it to a row subset
    /// (indices into the session's current batch, output follows their
    /// order), then refine to `plan`.  The session closes after the
    /// pass unless `keep`.  Same-plan fire-and-forget refines drained in
    /// one dispatch window may be merged into one backend dispatch.
    Refine {
        session: SessionId,
        rows: Option<Vec<usize>>,
        plan: PrecisionPlan,
        keep: bool,
        reply: mpsc::SyncSender<Result<EngineOutput>>,
    },
    /// Rebase a pooled (streaming) session onto a new frame of the same
    /// geometry via [`InferenceSession::rebase_input`], reusing every
    /// unchanged row's accumulator.  The session always stays in the
    /// pool (streams are long-lived); the reply carries its id.
    SubmitFrame {
        session: SessionId,
        /// Row-major `[batch, H, W, C]` frame, same geometry as the
        /// session's `Begin`.
        x: Vec<f32>,
        reply: mpsc::SyncSender<Result<EngineOutput>>,
    },
    /// Escalate a *fork* of a pooled session: clone it, narrow the
    /// clone to `rows`, refine it to `plan`, reply with the clone's
    /// output and drop it — the pooled session itself stays untouched
    /// at its stage-1 precision for the stream's next frame.
    ForkEscalate {
        session: SessionId,
        rows: Option<Vec<usize>>,
        plan: PrecisionPlan,
        reply: mpsc::SyncSender<Result<EngineOutput>>,
    },
    /// Pin (or release) a pooled session against LRU eviction — stream
    /// sessions hold their slot while the stream is live.  With `reply:
    /// None` this is fire-and-forget like `Close`; with a reply channel
    /// the outcome is confirmed, and pinning a missing id reports *why*
    /// it is missing (a fully-pinned pool's bounce is a named
    /// `(overloaded)` error, not a silent no-op).
    SetPinned {
        session: SessionId,
        pinned: bool,
        reply: Option<mpsc::SyncSender<Result<()>>>,
    },
    /// Drop a pooled session (e.g. nothing escalated).  Idempotent.
    Close { session: SessionId },
}

/// Result of one engine pass.
#[derive(Debug)]
pub struct EngineOutput {
    pub exec: Execution,
    /// The session left open for escalation (`keep` jobs only).
    pub session: Option<SessionId>,
    /// Gated adds actually charged by the pass over the rows submitted.
    /// Stateless backends (PJRT artifacts) report 0 and consumers (the
    /// coordinator's metrics) fall back to a geometric estimate.
    pub gated_adds: u64,
    /// Accumulator adds the backend actually executed for this pass
    /// (session caches and the O(Δ) delta paths shrink it) — the "real
    /// speed" companion to the hardware-model charge.
    pub executed_adds: u64,
    /// Backend-measured wall time of the pass, in nanoseconds.
    pub backend_ns: u64,
    /// This output came out of a merged dispatch (several refine jobs
    /// coalesced into one backend call).
    pub merged: bool,
    /// Which contraction inner loop the backend reported for the pass
    /// ([`KernelPath::Other`] for backends that do not tag theirs).
    pub kernel_path: KernelPath,
}

/// Bounded LRU slab of open sessions.  Ids are monotonic and never
/// reused; retired ids (closed, evicted, or consumed by a completed or
/// failed refine) keep a human-readable reason so a late or duplicate
/// `Refine` names what happened instead of "unknown session".
struct SessionPool {
    cap: usize,
    slots: BTreeMap<SessionId, Box<dyn InferenceSession>>,
    /// Least recently used first.
    lru: VecDeque<SessionId>,
    /// Sessions exempt from LRU eviction while live (streaming sessions
    /// pinned by their stream).  Pinned sessions still count toward
    /// capacity, so a fully pinned pool can exceed `cap` — that is the
    /// stream registry's admission problem, not the pool's.
    pinned: BTreeSet<SessionId>,
    retired: BTreeMap<SessionId, String>,
    next_id: SessionId,
    stats: Arc<EngineStats>,
}

impl SessionPool {
    fn new(cap: usize, stats: Arc<EngineStats>) -> SessionPool {
        SessionPool {
            cap: cap.max(1),
            slots: BTreeMap::new(),
            lru: VecDeque::new(),
            pinned: BTreeSet::new(),
            retired: BTreeMap::new(),
            next_id: 1,
            stats,
        }
    }

    fn sync_gauges(&self) {
        let open = self.slots.len() as u64;
        self.stats.sessions_open.store(open, Ordering::Relaxed);
        self.stats.sessions_peak.fetch_max(open, Ordering::Relaxed);
    }

    fn retire(&mut self, id: SessionId, reason: String) {
        self.retired.insert(id, reason);
        if self.retired.len() > 1024 {
            // ids are monotonic: forget the oldest retirements
            let cutoff = self.next_id.saturating_sub(1024);
            self.retired.retain(|&k, _| k >= cutoff);
        }
    }

    /// Insert a session at the most-recently-used end, evicting the LRU
    /// session(s) beyond capacity.
    fn insert(&mut self, sess: Box<dyn InferenceSession>) -> SessionId {
        let id = self.next_id;
        self.next_id += 1;
        self.slots.insert(id, sess);
        self.lru.push_back(id);
        self.evict_over_cap();
        self.sync_gauges();
        id
    }

    fn evict_over_cap(&mut self) {
        // pinned ids are skipped (and kept in LRU order); when only
        // pinned sessions remain, eviction stops rather than livelock
        let mut kept: VecDeque<SessionId> = VecDeque::new();
        while self.slots.len() > self.cap {
            let Some(old) = self.lru.pop_front() else { break };
            if self.pinned.contains(&old) {
                kept.push_back(old);
                continue;
            }
            self.slots.remove(&old);
            if self.pinned.len() >= self.cap {
                // every capacity slot is pinned: the victim is the
                // newcomer itself.  That is a capacity *bounce* — a
                // named retryable overload, not an LRU eviction.
                self.retire(
                    old,
                    format!(
                        "session {old} was bounced: pool fully pinned at capacity {} \
                         {OVERLOADED}: retry later",
                        self.cap
                    ),
                );
                self.stats.pool_bounces.fetch_add(1, Ordering::Relaxed);
            } else {
                self.retire(
                    old,
                    format!(
                        "session {old} was evicted from the pool (LRU, capacity {})",
                        self.cap
                    ),
                );
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        while let Some(id) = kept.pop_back() {
            self.lru.push_front(id);
        }
    }

    /// Mark a resident session exempt from (or again subject to) LRU
    /// eviction.  Unpinning re-applies the capacity bound immediately.
    /// Pinning a non-resident id is an error naming its retirement —
    /// the caller may have raced an eviction or a fully-pinned bounce.
    fn set_pinned(&mut self, id: SessionId, pinned: bool) -> Result<()> {
        if pinned {
            if self.slots.contains_key(&id) {
                self.pinned.insert(id);
                Ok(())
            } else {
                Err(match self.retired.get(&id) {
                    Some(reason) => anyhow!("cannot pin: {reason}"),
                    None => anyhow!("cannot pin unknown engine session {id}"),
                })
            }
        } else {
            if self.pinned.remove(&id) {
                self.evict_over_cap();
                self.sync_gauges();
            }
            Ok(())
        }
    }

    /// Remove a session for use; the reason a missing id is missing is
    /// part of the error.
    fn take(&mut self, id: SessionId) -> Result<Box<dyn InferenceSession>> {
        match self.slots.remove(&id) {
            Some(s) => {
                self.lru.retain(|&x| x != id);
                self.sync_gauges();
                Ok(s)
            }
            None => Err(match self.retired.get(&id) {
                Some(reason) => anyhow!("{reason}"),
                None => anyhow!("unknown engine session {id}"),
            }),
        }
    }

    /// Borrow a resident session without touching LRU order (the fork
    /// path reads it in place); a missing id names its retirement.
    fn peek(&self, id: SessionId) -> Result<&dyn InferenceSession> {
        match self.slots.get(&id) {
            Some(s) => Ok(s.as_ref()),
            None => Err(match self.retired.get(&id) {
                Some(reason) => anyhow!("{reason}"),
                None => anyhow!("unknown engine session {id}"),
            }),
        }
    }

    /// Return a taken session under its existing id (a kept refine);
    /// touches it to most-recently-used.
    fn put_back(&mut self, id: SessionId, sess: Box<dyn InferenceSession>) {
        self.slots.insert(id, sess);
        self.lru.push_back(id);
        self.evict_over_cap();
        self.sync_gauges();
    }

    /// Explicit close; idempotent, and the id is retired so later jobs
    /// name the close (never a recycled session).
    fn close(&mut self, id: SessionId) {
        if self.slots.remove(&id).is_some() {
            self.lru.retain(|&x| x != id);
        }
        self.pinned.remove(&id);
        if id < self.next_id && !self.retired.contains_key(&id) {
            self.retire(id, format!("session {id} was closed"));
        }
        self.sync_gauges();
    }
}

/// One pending refine of a dispatch window.
struct RefineReq {
    session: SessionId,
    rows: Option<Vec<usize>>,
    plan: PrecisionPlan,
    keep: bool,
    reply: mpsc::SyncSender<Result<EngineOutput>>,
}

/// One pending fire-and-forget begin of a dispatch window.
struct BeginReq {
    plan: PrecisionPlan,
    x: Vec<f32>,
    batch: usize,
    seed: u64,
    reply: mpsc::SyncSender<Result<EngineOutput>>,
}

/// Handle to the engine thread.
pub struct Engine {
    tx: QueueTx<EngineJob>,
    handle: Option<JoinHandle<()>>,
    /// Recent backend/session failures, for post-mortem `submit`s and
    /// cascade diagnosis.
    fail: Arc<ErrorRing>,
    stats: Arc<EngineStats>,
}

impl Engine {
    /// Spawn the engine thread over a backend factory with the default
    /// pool bound.  The factory runs on the engine thread; construction
    /// failures propagate out of `spawn` (and are recorded for later
    /// `last_error` queries).
    pub fn spawn(factory: BackendFactory) -> Result<Engine> {
        Engine::spawn_with(factory, EngineConfig::default())
    }

    /// [`Engine::spawn`] with explicit tuning.
    pub fn spawn_with(factory: BackendFactory, cfg: EngineConfig) -> Result<Engine> {
        let fail = Arc::new(ErrorRing::default());
        let stats = Arc::new(EngineStats::default());
        let fail_worker = fail.clone();
        let stats_worker = stats.clone();
        let (tx, rx) = bounded_queue::<EngineJob>("engine admission", cfg.queue_cap);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let handle = std::thread::Builder::new()
            .name("psb-engine".into())
            .spawn(move || {
                let backend: Box<dyn Backend> = match no_unwind("construction", factory) {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        fail_worker.push(format!("{e:#}"));
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let hwc = backend.input_hwc();
                let mut pool = SessionPool::new(cfg.pool_cap, stats_worker.clone());
                while let Ok(first) = rx.recv() {
                    // one dispatch window: everything already queued
                    let window = crate::coordinator::batcher::drain_ready(&rx, first, MAX_DRAIN);
                    let mut refines: Vec<RefineReq> = Vec::new();
                    // fire-and-forget begins accumulate too: nothing in
                    // the window can reference a session they have not
                    // created yet, so deferring them to the window end
                    // (where same-identity ones merge) preserves order
                    let mut begins: Vec<BeginReq> = Vec::new();
                    for job in window {
                        match job {
                            EngineJob::Refine { session, rows, plan, keep, reply } => {
                                refines.push(RefineReq { session, rows, plan, keep, reply });
                            }
                            EngineJob::Begin { plan, x, batch, seed, keep: false, reply } => {
                                begins.push(BeginReq { plan, x, batch, seed, reply });
                            }
                            other => {
                                // preserve job order around order-sensitive jobs
                                dispatch_refines(
                                    backend.as_ref(),
                                    &mut pool,
                                    std::mem::take(&mut refines),
                                    &stats_worker,
                                    &fail_worker,
                                );
                                match other {
                                    EngineJob::Begin { plan, x, batch, seed, keep: _, reply } => {
                                        // keep == true: the session enters
                                        // the pool, so dispatch inline (a
                                        // merged begin cannot be split
                                        // back into pool slots)
                                        let result = begin_job(
                                            backend.as_ref(),
                                            hwc,
                                            plan,
                                            x,
                                            batch,
                                            seed,
                                        );
                                        let result = match result {
                                            Ok((sess, mut out)) => {
                                                out.session = Some(pool.insert(sess));
                                                stats_worker.note_kernel_path(out.kernel_path);
                                                Ok(out)
                                            }
                                            Err(e) => {
                                                fail_worker.push(format!("{e:#}"));
                                                Err(e)
                                            }
                                        };
                                        // receiver may have given up; dropping is fine
                                        let _ = reply.send(result);
                                    }
                                    EngineJob::SubmitFrame { session, x, reply } => {
                                        let result = submit_frame_job(
                                            hwc,
                                            &mut pool,
                                            session,
                                            x,
                                        );
                                        match &result {
                                            Ok(out) => {
                                                stats_worker
                                                    .stream_frames
                                                    .fetch_add(1, Ordering::Relaxed);
                                                stats_worker.note_kernel_path(out.kernel_path);
                                            }
                                            Err(e) => {
                                                fail_worker.push(format!("{e:#}"));
                                            }
                                        }
                                        let _ = reply.send(result);
                                    }
                                    EngineJob::ForkEscalate { session, rows, plan, reply } => {
                                        let result =
                                            fork_escalate_job(&pool, session, rows, &plan);
                                        match &result {
                                            Ok(out) => {
                                                stats_worker.note_kernel_path(out.kernel_path);
                                            }
                                            Err(e) => fail_worker.push(format!("{e:#}")),
                                        }
                                        let _ = reply.send(result);
                                    }
                                    EngineJob::SetPinned { session, pinned, reply } => {
                                        let result = pool.set_pinned(session, pinned);
                                        if let Err(e) = &result {
                                            fail_worker.push(format!("{e:#}"));
                                        }
                                        if let Some(reply) = reply {
                                            let _ = reply.send(result);
                                        }
                                    }
                                    EngineJob::Close { session } => pool.close(session),
                                    EngineJob::Refine { .. } => unreachable!("matched above"),
                                }
                            }
                        }
                    }
                    dispatch_refines(
                        backend.as_ref(),
                        &mut pool,
                        refines,
                        &stats_worker,
                        &fail_worker,
                    );
                    dispatch_begins(
                        backend.as_ref(),
                        hwc,
                        begins,
                        &stats_worker,
                        &fail_worker,
                    );
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine { tx, handle: Some(handle), fail, stats })
    }

    /// Enqueue a job (non-blocking).  Work jobs are refused with a
    /// named `(overloaded)` error once the bounded admission queue is
    /// full; control jobs (`Close`, pin/unpin) always land — dropping a
    /// cleanup job would leak a pool slot forever.  A send against a
    /// dead engine reports the recorded root cause, not just "shut
    /// down".
    pub fn submit(&self, job: EngineJob) -> Result<()> {
        let control = matches!(job, EngineJob::SetPinned { .. } | EngineJob::Close { .. });
        let sent = if control { self.tx.send_unbounded(job) } else { self.tx.send(job) };
        match sent {
            Ok(()) => Ok(()),
            Err(QueueSendError::Full(_)) => Err(self.tx.full_error()),
            Err(QueueSendError::Disconnected(_)) => Err(match self.last_error() {
                Some(cause) => {
                    anyhow!("engine thread has shut down (last backend failure: {cause})")
                }
                None => anyhow!("engine thread has shut down"),
            }),
        }
    }

    /// Most recent backend/session failure observed by the engine.
    pub fn last_error(&self) -> Option<String> {
        self.fail.last()
    }

    /// Recent backend/session failures, oldest first (bounded ring) —
    /// the post-mortem view of a cascade, not just its last symptom.
    pub fn recent_errors(&self) -> Vec<String> {
        self.fail.to_vec()
    }

    /// Live pool / merge counters.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Convenience: run one batch in a throwaway session and wait.
    pub fn run_once(
        &self,
        plan: PrecisionPlan,
        x: Vec<f32>,
        batch: usize,
        seed: u64,
    ) -> Result<EngineOutput> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.submit(EngineJob::Begin { plan, x, batch, seed, keep: false, reply })?;
        self.wait(rx)
    }

    /// Run one batch, keeping the session open in the pool for
    /// escalation.
    pub fn begin_session(
        &self,
        plan: PrecisionPlan,
        x: Vec<f32>,
        batch: usize,
        seed: u64,
    ) -> Result<EngineOutput> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.submit(EngineJob::Begin { plan, x, batch, seed, keep: true, reply })?;
        self.wait(rx)
    }

    /// Escalate (and close) a pooled session, optionally narrowed to a
    /// row subset first.
    pub fn refine_session(
        &self,
        session: SessionId,
        rows: Option<Vec<usize>>,
        plan: PrecisionPlan,
    ) -> Result<EngineOutput> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.submit(EngineJob::Refine { session, rows, plan, keep: false, reply })?;
        self.wait(rx)
    }

    /// Rebase a pooled streaming session onto a new frame and wait —
    /// the per-frame serving call of a stream.
    pub fn submit_frame(&self, session: SessionId, x: Vec<f32>) -> Result<EngineOutput> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.submit(EngineJob::SubmitFrame { session, x, reply })?;
        self.wait(rx)
    }

    /// Escalate a *fork* of a pooled session (narrow + refine the
    /// fork), leaving the pooled session itself untouched for the
    /// stream's next frame.
    pub fn fork_escalate(
        &self,
        session: SessionId,
        rows: Option<Vec<usize>>,
        plan: PrecisionPlan,
    ) -> Result<EngineOutput> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.submit(EngineJob::ForkEscalate { session, rows, plan, reply })?;
        self.wait(rx)
    }

    /// Pin or release a pooled session against LRU eviction
    /// (fire-and-forget).
    pub fn pin_session(&self, session: SessionId, pinned: bool) -> Result<()> {
        self.submit(EngineJob::SetPinned { session, pinned, reply: None })
    }

    /// Pin a pooled session and *confirm* the pin took: a session that
    /// was bounced by a fully-pinned pool answers with its named
    /// `(overloaded)` bounce reason instead of silently staying
    /// unpinned — the stream registry's admission check.
    pub fn pin_session_checked(&self, session: SessionId, pinned: bool) -> Result<()> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.submit(EngineJob::SetPinned { session, pinned, reply: Some(reply) })?;
        rx.recv().map_err(|_| match self.last_error() {
            Some(cause) => anyhow!("engine dropped the job (last backend failure: {cause})"),
            None => anyhow!("engine dropped the job"),
        })?
    }

    /// Drop a pooled session.
    pub fn close_session(&self, session: SessionId) -> Result<()> {
        self.submit(EngineJob::Close { session })
    }

    fn wait(&self, rx: mpsc::Receiver<Result<EngineOutput>>) -> Result<EngineOutput> {
        rx.recv().map_err(|_| match self.last_error() {
            Some(cause) => anyhow!("engine dropped the job (last backend failure: {cause})"),
            None => anyhow!("engine dropped the job"),
        })?
    }
}

/// Dispatch one window's refine jobs: take + narrow every target
/// session, merge the compatible ones (same target plan, not kept) into
/// one backend dispatch, run the rest serially.
fn dispatch_refines(
    backend: &dyn Backend,
    pool: &mut SessionPool,
    refines: Vec<RefineReq>,
    stats: &EngineStats,
    fail: &ErrorRing,
) {
    if refines.is_empty() {
        return;
    }
    // partition into merge groups by target plan; kept refines always
    // dispatch alone (a merged session cannot be split back into pool
    // slots)
    let mut groups: Vec<(PrecisionPlan, Vec<RefineReq>)> = Vec::new();
    let mut singles: Vec<RefineReq> = Vec::new();
    for req in refines {
        if req.keep {
            singles.push(req);
            continue;
        }
        match groups.iter().position(|(p, _)| *p == req.plan) {
            Some(i) => groups[i].1.push(req),
            None => groups.push((req.plan.clone(), vec![req])),
        }
    }
    for (plan, group) in groups {
        if group.len() < 2 {
            singles.extend(group);
            continue;
        }
        // take + narrow each member; failures answer that member alone
        let mut ready: Vec<(RefineReq, Box<dyn InferenceSession>)> = Vec::new();
        for req in group {
            match take_and_narrow(pool, &req) {
                Ok(sess) => ready.push((req, sess)),
                Err(e) => {
                    fail.push(format!("{e:#}"));
                    let _ = req.reply.send(Err(e));
                }
            }
        }
        if ready.len() < 2 {
            for (req, sess) in ready {
                refine_in_hand(pool, req, sess, stats, fail);
            }
            continue;
        }
        let (reqs, parts): (Vec<RefineReq>, Vec<Box<dyn InferenceSession>>) =
            ready.into_iter().unzip();
        match no_unwind("session merge", || backend.merge_sessions(parts)) {
            Ok(MergeOutcome::Merged(mut merged)) => {
                let parts_n = reqs.len() as u64;
                match no_unwind("merged refine", || merged.refine(&plan)) {
                    Ok(_aggregate) => {
                        stats.merges.fetch_add(1, Ordering::Relaxed);
                        stats.runs_saved.fetch_add(parts_n - 1, Ordering::Relaxed);
                        let outs = split_merged_outputs(merged.as_ref());
                        debug_assert_eq!(outs.len(), reqs.len());
                        for (req, out) in reqs.into_iter().zip(outs) {
                            stats.note_kernel_path(out.kernel_path);
                            pool.retire(
                                req.session,
                                format!(
                                    "session {} was closed by its completed (merged) refine",
                                    req.session
                                ),
                            );
                            let _ = req.reply.send(Ok(out));
                        }
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        fail.push(msg.clone());
                        for req in reqs {
                            pool.retire(
                                req.session,
                                format!(
                                    "session {} was dropped by a failed merged refine: {msg}",
                                    req.session
                                ),
                            );
                            let _ = req.reply.send(Err(anyhow!("merged dispatch failed: {msg}")));
                        }
                    }
                }
            }
            Ok(MergeOutcome::Unsupported(parts)) => {
                for (req, sess) in reqs.into_iter().zip(parts) {
                    refine_in_hand(pool, req, sess, stats, fail);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                fail.push(msg.clone());
                for req in reqs {
                    let _ = req.reply.send(Err(anyhow!("session merge failed: {msg}")));
                }
            }
        }
    }
    for req in singles {
        match take_and_narrow(pool, &req) {
            Ok(sess) => refine_in_hand(pool, req, sess, stats, fail),
            Err(e) => {
                fail.push(format!("{e:#}"));
                let _ = req.reply.send(Err(e));
            }
        }
    }
}

/// Dispatch one window's fire-and-forget `Begin` jobs: jobs with the
/// same `(plan, seed)` coalesce into **one** concatenated backend pass
/// (a stage-1 frame burst shares one artifact run on stateless
/// backends), split back per job afterwards.  Bit-identity holds for
/// every shipped backend because filter draws are batch-shared (they
/// depend on the seed, never the batch size) and rows are computed
/// independently — a row's logits in the concatenated pass are exactly
/// its logits in a solo pass under the same seed.
fn dispatch_begins(
    backend: &dyn Backend,
    hwc: (usize, usize, usize),
    begins: Vec<BeginReq>,
    stats: &EngineStats,
    fail: &ErrorRing,
) {
    if begins.is_empty() {
        return;
    }
    let mut groups: Vec<(PrecisionPlan, u64, Vec<BeginReq>)> = Vec::new();
    for req in begins {
        match groups.iter().position(|(p, s, _)| *p == req.plan && *s == req.seed) {
            Some(i) => groups[i].2.push(req),
            None => groups.push((req.plan.clone(), req.seed, vec![req])),
        }
    }
    let (h, w, c) = hwc;
    let img = h * w * c;
    for (plan, seed, group) in groups {
        if group.len() < 2 {
            for req in group {
                serve_begin(backend, hwc, req, stats, fail);
            }
            continue;
        }
        // validate each member's geometry up front so one malformed job
        // fails alone instead of poisoning the merged pass
        let mut ready: Vec<BeginReq> = Vec::new();
        for req in group {
            if req.batch > 0 && req.x.len() == req.batch * img {
                ready.push(req);
            } else {
                let e = anyhow!(
                    "input size {} != batch {} × {h}×{w}×{c}",
                    req.x.len(),
                    req.batch
                );
                fail.push(format!("{e:#}"));
                let _ = req.reply.send(Err(e));
            }
        }
        if ready.len() < 2 {
            for req in ready {
                serve_begin(backend, hwc, req, stats, fail);
            }
            continue;
        }
        let parts: Vec<usize> = ready.iter().map(|r| r.batch).collect();
        let total: usize = parts.iter().sum();
        let mut x = Vec::with_capacity(total * img);
        for req in &ready {
            x.extend_from_slice(&req.x);
        }
        match begin_job(backend, hwc, plan, x, total, seed) {
            Ok((sess, _)) => {
                stats.merges.fetch_add(1, Ordering::Relaxed);
                stats.runs_saved.fetch_add(ready.len() as u64 - 1, Ordering::Relaxed);
                let step = sess.cost_report().last_step().cloned().unwrap_or_default();
                let outs = split_begun_outputs(sess.as_ref(), &step, &parts);
                debug_assert_eq!(outs.len(), ready.len());
                for (req, out) in ready.into_iter().zip(outs) {
                    stats.note_kernel_path(out.kernel_path);
                    let _ = req.reply.send(Ok(out));
                }
            }
            Err(e) => {
                // geometry was pre-validated, so a merged-begin failure
                // (bad plan, backend fault) is shared by every member
                let msg = format!("{e:#}");
                fail.push(msg.clone());
                for req in ready {
                    let _ = req.reply.send(Err(anyhow!("merged begin failed: {msg}")));
                }
            }
        }
    }
}

/// Serial fire-and-forget begin (the non-merged path).
fn serve_begin(
    backend: &dyn Backend,
    hwc: (usize, usize, usize),
    req: BeginReq,
    stats: &EngineStats,
    fail: &ErrorRing,
) {
    let result = match begin_job(backend, hwc, req.plan, req.x, req.batch, req.seed) {
        Ok((_sess, out)) => {
            stats.note_kernel_path(out.kernel_path);
            Ok(out)
        }
        Err(e) => {
            fail.push(format!("{e:#}"));
            Err(e)
        }
    };
    let _ = req.reply.send(result);
}

/// Split a merged begin's single pass back into per-job outputs.  Rows
/// split by each job's batch extent; the charge splits proportionally by
/// rows, which is *exact* for the per-row-billed backends (every layer's
/// charge is linear in the batch) and the documented estimate for
/// stateless ones.
fn split_begun_outputs(
    sess: &dyn InferenceSession,
    step: &StepReport,
    parts: &[usize],
) -> Vec<EngineOutput> {
    let logits = sess.logits();
    let nc = logits.shape.get(1).copied().unwrap_or(0);
    let feat = sess.feat();
    let total: usize = parts.iter().sum::<usize>().max(1);
    let mut outs = Vec::with_capacity(parts.len());
    let mut off = 0usize;
    for &rows in parts {
        let l = logits.data[off * nc..(off + rows) * nc].to_vec();
        let (f, fshape) = match feat {
            Some(f) if f.shape.len() == 4 => {
                let flen = f.shape[1] * f.shape[2] * f.shape[3];
                (
                    f.data[off * flen..(off + rows) * flen].to_vec(),
                    [rows, f.shape[1], f.shape[2], f.shape[3]],
                )
            }
            _ => (Vec::new(), [rows, 0, 0, 0]),
        };
        let share = |v: u64| v * rows as u64 / total as u64;
        outs.push(EngineOutput {
            exec: Execution { logits: l, feat: f, feat_shape: fshape },
            session: None,
            gated_adds: share(step.costs.gated_adds),
            executed_adds: share(step.executed_adds),
            backend_ns: share(step.elapsed_ns),
            merged: true,
            kernel_path: step.kernel_path,
        });
        off += rows;
    }
    outs
}

/// Serve one streaming frame: take the pooled session, rebase it onto
/// the new frame, and put it back (streams always keep their session).
/// A missing id answers with its retirement reason — a reclaimed stream
/// names the reclaim, never a dropped reply.
fn submit_frame_job(
    (h, w, c): (usize, usize, usize),
    pool: &mut SessionPool,
    id: SessionId,
    x: Vec<f32>,
) -> Result<EngineOutput> {
    let img = h * w * c;
    anyhow::ensure!(
        img > 0 && x.len() % img == 0 && !x.is_empty(),
        "frame size {} is not a multiple of {h}×{w}×{c}",
        x.len()
    );
    let batch = x.len() / img;
    let mut sess = pool.take(id)?;
    let xt = Tensor::from_vec(x, &[batch, h, w, c]);
    match no_unwind("rebase", || sess.rebase_input(&xt)) {
        Ok(step) => {
            let mut out = output_of(sess.as_ref(), &step);
            pool.put_back(id, sess);
            out.session = Some(id);
            Ok(out)
        }
        Err(e) => {
            // the session's cached state no longer matches any frame
            pool.retire(
                id,
                format!("session {id} was dropped by a failed frame rebase: {e:#}"),
            );
            pool.pinned.remove(&id);
            Err(e)
        }
    }
}

/// Stage-2 escalation of a stream: fork the pooled session, narrow and
/// refine the fork, drop it — the pooled session stays at its stage-1
/// precision for the next frame.
fn fork_escalate_job(
    pool: &SessionPool,
    id: SessionId,
    rows: Option<Vec<usize>>,
    plan: &PrecisionPlan,
) -> Result<EngineOutput> {
    let sess = pool.peek(id)?;
    let (fork, step) = no_unwind("fork-escalate", || {
        let mut fork = sess.fork()?;
        if let Some(rows) = &rows {
            fork.narrow(rows)?;
        }
        let step = fork.refine(plan)?;
        Ok((fork, step))
    })?;
    Ok(output_of(fork.as_ref(), &step))
}

/// Pull a refine's session out of the pool and narrow it to the
/// requested rows.  A narrow failure drops the session (its row state is
/// unknown), mirroring the serial path — the id is retired with that
/// reason so later jobs against it are diagnosable.
fn take_and_narrow(pool: &mut SessionPool, req: &RefineReq) -> Result<Box<dyn InferenceSession>> {
    let mut sess = pool.take(req.session)?;
    if let Some(rows) = &req.rows {
        if let Err(e) = no_unwind("narrow", || sess.narrow(rows)) {
            pool.retire(
                req.session,
                format!("session {} was dropped by a failed narrow: {e:#}", req.session),
            );
            return Err(e);
        }
    }
    Ok(sess)
}

/// Serial refine of a session already taken (and narrowed) from the
/// pool.  Consumed (`keep == false`) and failed sessions retire their
/// id with the reason, so duplicate/late jobs name what happened.
fn refine_in_hand(
    pool: &mut SessionPool,
    req: RefineReq,
    mut sess: Box<dyn InferenceSession>,
    stats: &EngineStats,
    fail: &ErrorRing,
) {
    let result = match no_unwind("refine", || sess.refine(&req.plan)) {
        Ok(step) => {
            let mut out = output_of(sess.as_ref(), &step);
            stats.note_kernel_path(out.kernel_path);
            if req.keep {
                pool.put_back(req.session, sess);
                out.session = Some(req.session);
            } else {
                pool.retire(
                    req.session,
                    format!("session {} was closed by its completed refine", req.session),
                );
            }
            Ok(out)
        }
        Err(e) => {
            pool.retire(
                req.session,
                format!("session {} was dropped by a failed refine: {e:#}", req.session),
            );
            fail.push(format!("{e:#}"));
            Err(e)
        }
    };
    let _ = req.reply.send(result);
}

/// Split a merged session's pass back into per-part outputs, using the
/// per-part rows and step reports the merge contract guarantees.
fn split_merged_outputs(merged: &dyn InferenceSession) -> Vec<EngineOutput> {
    let steps = merged.part_steps();
    let parts = merged.part_rows();
    let logits = merged.logits();
    let nc = logits.shape.get(1).copied().unwrap_or(0);
    let feat = merged.feat();
    let mut outs = Vec::with_capacity(parts.len());
    let mut off = 0usize;
    for (i, &rows) in parts.iter().enumerate() {
        let l = logits.data[off * nc..(off + rows) * nc].to_vec();
        let (f, fshape) = match feat {
            Some(f) if f.shape.len() == 4 => {
                let flen = f.shape[1] * f.shape[2] * f.shape[3];
                (
                    f.data[off * flen..(off + rows) * flen].to_vec(),
                    [rows, f.shape[1], f.shape[2], f.shape[3]],
                )
            }
            _ => (Vec::new(), [rows, 0, 0, 0]),
        };
        let step = steps.get(i).cloned().unwrap_or_default();
        outs.push(EngineOutput {
            exec: Execution { logits: l, feat: f, feat_shape: fshape },
            session: None,
            gated_adds: step.costs.gated_adds,
            executed_adds: step.executed_adds,
            backend_ns: step.elapsed_ns,
            merged: true,
            kernel_path: step.kernel_path,
        });
        off += rows;
    }
    outs
}

fn begin_job(
    backend: &dyn Backend,
    (h, w, c): (usize, usize, usize),
    plan: PrecisionPlan,
    x: Vec<f32>,
    batch: usize,
    seed: u64,
) -> Result<(Box<dyn InferenceSession>, EngineOutput)> {
    anyhow::ensure!(
        x.len() == batch * h * w * c,
        "input size {} != batch {batch} × {h}×{w}×{c}",
        x.len()
    );
    let xt = Tensor::from_vec(x, &[batch, h, w, c]);
    let (sess, step) = no_unwind("begin", || {
        let mut sess = backend.open(&plan)?;
        let step = sess.begin(&xt, seed)?;
        Ok((sess, step))
    })?;
    let out = output_of(sess.as_ref(), &step);
    Ok((sess, out))
}

fn output_of(sess: &dyn InferenceSession, step: &StepReport) -> EngineOutput {
    let logits = sess.logits();
    let (feat, feat_shape) = match sess.feat() {
        Some(f) => {
            let s = &f.shape;
            let dim = |i: usize| s.get(i).copied().unwrap_or(1);
            (f.data.clone(), [dim(0), dim(1), dim(2), dim(3)])
        }
        None => (Vec::new(), [logits.shape.first().copied().unwrap_or(0), 0, 0, 0]),
    };
    EngineOutput {
        exec: Execution { logits: logits.data.clone(), feat, feat_shape },
        session: None,
        gated_adds: step.costs.gated_adds,
        executed_adds: step.executed_adds,
        backend_ns: step.elapsed_ns,
        merged: false,
        kernel_path: step.kernel_path,
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Closing the channel ends the engine loop.
        let (tx, _) = bounded_queue("engine shutdown", 0);
        drop(std::mem::replace(&mut self.tx, tx));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
