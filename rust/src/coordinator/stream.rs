//! Temporal delta serving: named streams over pinned pooled sessions.
//!
//! A video/sensor client serves *frames*, not independent requests:
//! consecutive inputs differ in a small region and agree everywhere
//! else.  PSB's capacitor representation turns that temporal redundancy
//! into compute savings — a begun session's cached accumulators are a
//! pure function of the input lowering and the batch-shared counts, so
//! [`crate::backend::InferenceSession::rebase_input`] can move the
//! session onto the next frame recomputing only the changed rows (plus
//! conv halo), with logits bit-identical to a fresh pass.
//!
//! The [`StreamRegistry`] is the serving-layer face of that op:
//!
//! * each stream id owns one engine session, **pinned** in the engine's
//!   session pool (exempt from LRU eviction while the stream lives);
//! * every frame is a [`crate::coordinator::engine::EngineJob::SubmitFrame`]
//!   — an O(Δ) rebase of the pinned session, sharing the engine's
//!   dispatch windows with ordinary serving traffic;
//! * per frame, the stage-1 entropy signal can still escalate: the
//!   registry refines a *fork* of the pinned session at `n_high`
//!   ([`Engine::fork_escalate`]), leaving the pinned session at `n_low`
//!   for the next frame's rebase;
//! * streams idle past [`StreamConfig::idle_ttl`] are reclaimed (their
//!   session unpinned and closed) by a sweep that runs on every submit,
//!   and a later frame on a reclaimed id answers a **named error**
//!   carrying the reclaim reason — never a dropped reply.
//!
//! Frame traffic runs **supervised**
//! ([`crate::coordinator::supervisor::Supervisor`]): a faulted rebase is
//! retried and, failing that, *resurrected* through the rebase contract
//! itself — a fresh pinned `begin` on the new frame under the stream's
//! recorded `(plan, seed)`, bit-identical to the rebase that failed —
//! and the reply is flagged [`ServedVia::Recovered`].  A frame whose
//! escalation cannot run (breaker open, retries exhausted) serves its
//! rebased `n_low` answer flagged [`ServedVia::Degraded`].  Idle-TTL
//! bookkeeping reads the registry's [`Clock`], so reclamation is
//! test-drivable on a virtual clock.
//!
//! Backends whose sessions cannot rebase (the stateless PJRT artifact
//! runtime) fail the second frame with the backend's own message; the
//! stream then retires with that reason, so callers learn the capability
//! gap loudly instead of silently paying fresh passes.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::clock::Clock;
use crate::coordinator::engine::{Engine, EngineOutput, SessionId};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::overload::BrownoutController;
use crate::coordinator::scheduler::{EscalationPolicy, Scheduler};
use crate::coordinator::server::{ClassifyResponse, ServedVia};
use crate::coordinator::supervisor::Supervisor;
use crate::precision::PrecisionPlan;
use crate::sim::layers::softmax_rows;

/// Caller-chosen stream identifier (e.g. a camera or connection id).
pub type StreamId = u64;

/// Streaming knobs.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Escalation policy for per-frame stage-2 refinement (each stream
    /// keeps its own adaptive entropy threshold).
    pub policy: EscalationPolicy,
    /// Streams with no frame for this long are reclaimed — their pinned
    /// session is released back to the pool's LRU discipline and closed.
    /// The sweep runs on every submit (no background thread).
    pub idle_ttl: Duration,
    /// Base seed for the per-stream filter-sample streams.
    pub seed: u64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            policy: EscalationPolicy::default(),
            idle_ttl: Duration::from_secs(30),
            seed: 11,
        }
    }
}

/// One live stream: its pinned session and the last served frame.
struct StreamEntry {
    session: SessionId,
    /// Per-stream adaptive escalation threshold (EWMA of frame
    /// entropies) — a static scene self-calibrates independently of a
    /// busy one.
    scheduler: Scheduler,
    /// The previous frame, kept to measure how much of each new frame
    /// actually changed (the registry's reuse accounting; the backend
    /// diffs quantized values itself and may reuse even more).
    last_image: Vec<f32>,
    /// When the last frame arrived, on the registry's [`Clock`].
    last_seen: Duration,
    /// Frames served on this stream, the opening `begin` included.
    frames: u64,
}

#[derive(Default)]
struct Inner {
    live: BTreeMap<StreamId, StreamEntry>,
    /// Why a stream went away — the named error any later frame gets.
    retired: BTreeMap<StreamId, String>,
}

/// Frame arrival order, per stream.  Each submitted frame takes a
/// global sequence number *before* queueing on the registry mutex, so
/// under brownout a frame that finds a newer arrival recorded for its
/// stream knows it is stale — latest frame wins, deterministically,
/// regardless of mutex wake order.
#[derive(Default)]
struct Arrivals {
    ctr: u64,
    latest: BTreeMap<StreamId, u64>,
}

/// Registry of live streams over one engine.  All engine traffic is
/// serialized by the engine thread anyway, so the registry holds one
/// mutex across a frame's engine calls.
pub struct StreamRegistry {
    engine: Arc<Engine>,
    supervisor: Arc<Supervisor>,
    metrics: Arc<Metrics>,
    cfg: StreamConfig,
    clock: Clock,
    image_len: usize,
    num_classes: usize,
    seed_ctr: AtomicU64,
    overload: Arc<BrownoutController>,
    arrivals: Mutex<Arrivals>,
    inner: Mutex<Inner>,
}

impl StreamRegistry {
    pub fn new(
        engine: Arc<Engine>,
        supervisor: Arc<Supervisor>,
        metrics: Arc<Metrics>,
        image_len: usize,
        num_classes: usize,
        cfg: StreamConfig,
        clock: Clock,
        overload: Arc<BrownoutController>,
    ) -> StreamRegistry {
        StreamRegistry {
            engine,
            supervisor,
            metrics,
            seed_ctr: AtomicU64::new(cfg.seed),
            cfg,
            clock,
            image_len,
            num_classes,
            overload,
            arrivals: Mutex::new(Arrivals::default()),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Serve one frame on `stream`, opening the stream on first use.
    ///
    /// The opening frame is a fresh `begin` (pinned into the pool);
    /// every later frame rebases the pinned session in O(changed rows +
    /// halo) and answers with [`ServedVia::Stream`] — or
    /// [`ServedVia::Recovered`] when the supervisor had to retry or
    /// resurrect the session (the answer is still bit-exact), or
    /// [`ServedVia::Degraded`] when a wanted escalation could not run
    /// (the rebased `n_low` answer serves instead).  A frame on a
    /// reclaimed or failed stream returns the retained reason.
    pub fn submit_frame(&self, stream: StreamId, image: Vec<f32>) -> Result<ClassifyResponse> {
        anyhow::ensure!(
            image.len() == self.image_len,
            "frame must be {} floats, got {}",
            self.image_len,
            image.len()
        );
        let start = self.clock.now();
        Metrics::inc(&self.metrics.requests);
        // Take an arrival sequence number BEFORE queueing on the
        // registry mutex: whichever frame arrived last owns the stream's
        // `latest` slot, independent of which thread wins the lock.
        let my_seq = {
            let mut a = crate::coordinator::lock_unpoisoned(&self.arrivals);
            a.ctr += 1;
            let seq = a.ctr;
            a.latest.insert(stream, seq);
            seq
        };
        let mut inner = crate::coordinator::lock_unpoisoned(&self.inner);
        self.sweep_idle(&mut inner, Some(stream));
        if let Some(reason) = inner.retired.get(&stream) {
            return Err(anyhow!("{reason}"));
        }
        // Under brownout, stale queued frames are coalesced away: if a
        // newer frame for this stream registered while we waited for the
        // lock, this one is already obsolete — drop it with a named
        // retryable reason and let the newest frame pay the rebase.
        if self.overload.coalesce_streams() {
            let stale = crate::coordinator::lock_unpoisoned(&self.arrivals)
                .latest
                .get(&stream)
                .is_some_and(|&l| l > my_seq);
            if stale {
                Metrics::inc(&self.metrics.frames_coalesced);
                return Err(anyhow!(
                    "stream {stream} frame superseded by a newer queued frame under brownout \
                     (overloaded): latest frame wins"
                ));
            }
        }
        let (out, recovered) = match inner.live.get_mut(&stream) {
            Some(entry) => {
                let frac = changed_fraction(&entry.last_image, &image);
                let reused = image.len() as u64 - (frac * image.len() as f64).round() as u64;
                match self.supervisor.submit_frame(entry.session, image.clone()) {
                    Ok((out, recovered)) => {
                        use std::sync::atomic::Ordering::Relaxed;
                        let stats = self.engine.stats();
                        stats.stream_rows_reused.fetch_add(reused, Relaxed);
                        stats.stream_frac_milli.fetch_add((frac * 1000.0).round() as u64, Relaxed);
                        // a resurrected session answers under a new id
                        if let Some(id) = out.session {
                            entry.session = id;
                        }
                        entry.last_image = image;
                        entry.last_seen = self.clock.now();
                        entry.frames += 1;
                        (out, recovered)
                    }
                    Err(err) => {
                        // rebase, retries, and resurrection all failed:
                        // retire the stream with the root cause so later
                        // frames get it too
                        let reason =
                            format!("stream {stream} was dropped by a failed frame rebase: {err:#}");
                        inner.live.remove(&stream);
                        inner.retired.insert(stream, reason.clone());
                        self.metrics.record_engine_error(&err);
                        self.metrics.sync_supervisor(self.supervisor.stats());
                        return Err(anyhow!("{reason}"));
                    }
                }
            }
            None => {
                let seed = self.seed_ctr.fetch_add(1, Ordering::Relaxed);
                let plan = PrecisionPlan::uniform(self.cfg.policy.n_low);
                let (out, recovered) =
                    self.supervisor.begin_session(plan, image.clone(), 1, seed)?;
                let Some(session) = out.session else {
                    return Err(anyhow!("engine returned no session handle for stream {stream}"));
                };
                // A fully-pinned pool at capacity bounces the newcomer
                // (retired with a named `(overloaded)` reason) rather
                // than evicting a live stream; surface that refusal to
                // the caller instead of serving an unpinned stream that
                // the next LRU pass would silently kill.
                if let Err(err) = self.engine.pin_session_checked(session, true) {
                    let _ = self.supervisor.close_session(session);
                    self.metrics.sync_engine(self.engine.stats());
                    return Err(anyhow!("stream {stream} could not open: {err:#}"));
                }
                inner.live.insert(
                    stream,
                    StreamEntry {
                        session,
                        scheduler: Scheduler::new(self.cfg.policy),
                        last_image: image,
                        last_seen: self.clock.now(),
                        frames: 1,
                    },
                );
                (out, recovered)
            }
        };
        self.record_pass(&out, self.cfg.policy.n_low as u64);
        // Stage-2 decision on the frame's entropy signal: escalate a
        // *fork* so the pinned session stays at n_low for the next
        // frame's rebase.  A failed escalation degrades to the rebased
        // answer instead of dropping the frame — explicitly flagged.
        let [_, _, _, fc] = out.exec.feat_shape;
        let entropy = if fc > 0 && !out.exec.feat.is_empty() {
            Scheduler::request_entropy(&out.exec.feat, fc)
        } else {
            0.0
        };
        let policy = self.cfg.policy;
        let escalate = policy.n_high > policy.n_low
            && inner.live.get_mut(&stream).is_some_and(|e| e.scheduler.decide(entropy));
        let session = inner.live.get(&stream).map(|e| e.session);
        let (final_out, escalated, degraded) = if escalate {
            let session = session.ok_or_else(|| anyhow!("stream {stream} vanished mid-frame"))?;
            match self.supervisor.fork_escalate(
                session,
                None,
                PrecisionPlan::uniform(policy.n_high),
            ) {
                Ok((hi, _retried)) => {
                    self.record_pass(&hi, (policy.n_high - policy.n_low) as u64);
                    Metrics::inc(&self.metrics.escalated);
                    Metrics::add(&self.metrics.samples_reused, policy.n_low as u64);
                    (hi, true, false)
                }
                Err(err) => {
                    self.metrics.record_engine_error(&err);
                    self.supervisor.stats().degraded.fetch_add(1, Ordering::Relaxed);
                    (out, false, true)
                }
            }
        } else {
            (out, false, false)
        };
        let probs = softmax_rows(&final_out.exec.logits, self.num_classes);
        let (class, confidence) = argmax_conf(&probs[..self.num_classes.min(probs.len())]);
        let latency = self.clock.now().saturating_sub(start);
        self.metrics.latency.record(latency);
        Metrics::inc(&self.metrics.completed);
        self.metrics.sync_engine(self.engine.stats());
        self.metrics.sync_supervisor(self.supervisor.stats());
        Ok(ClassifyResponse {
            class,
            confidence,
            escalated,
            n_used: if escalated { policy.n_high } else { policy.n_low },
            n_reused: if escalated { policy.n_low } else { 0 },
            latency,
            entropy,
            served: if degraded {
                ServedVia::Degraded
            } else if recovered {
                ServedVia::Recovered
            } else {
                ServedVia::Stream
            },
        })
    }

    /// Close a stream: unpin + drop its session (and its provenance
    /// record) and forget any retained retirement reason (the id becomes
    /// reusable).  Idempotent.
    pub fn close(&self, stream: StreamId) -> Result<()> {
        let mut inner = crate::coordinator::lock_unpoisoned(&self.inner);
        inner.retired.remove(&stream);
        crate::coordinator::lock_unpoisoned(&self.arrivals).latest.remove(&stream);
        if let Some(entry) = inner.live.remove(&stream) {
            self.engine.pin_session(entry.session, false)?;
            self.supervisor.close_session(entry.session)?;
        }
        Ok(())
    }

    /// Live stream count (diagnostics/tests).
    pub fn live_streams(&self) -> usize {
        crate::coordinator::lock_unpoisoned(&self.inner).live.len()
    }

    /// Frames served on a live stream (opening frame included); `None`
    /// once reclaimed or never opened.
    pub fn frames(&self, stream: StreamId) -> Option<u64> {
        crate::coordinator::lock_unpoisoned(&self.inner).live.get(&stream).map(|e| e.frames)
    }

    /// Reclaim every stream idle past the TTL except `keep` (the one
    /// being served right now).  Reclaimed ids keep a named reason.
    fn sweep_idle(&self, inner: &mut Inner, keep: Option<StreamId>) {
        let ttl = self.cfg.idle_ttl;
        let now = self.clock.now();
        let idle: Vec<StreamId> = inner
            .live
            .iter()
            .filter(|(id, e)| Some(**id) != keep && now.saturating_sub(e.last_seen) > ttl)
            .map(|(id, _)| *id)
            .collect();
        for id in idle {
            if let Some(entry) = inner.live.remove(&id) {
                let _ = self.engine.pin_session(entry.session, false);
                let _ = self.supervisor.close_session(entry.session);
                inner.retired.insert(
                    id,
                    format!(
                        "stream {id} was reclaimed after sitting idle past the {:?} TTL \
                         ({} frames served); open a new stream id or close({id}) to reuse it",
                        ttl, entry.frames
                    ),
                );
            }
        }
    }

    /// Record one engine pass into the serving metrics.
    fn record_pass(&self, out: &EngineOutput, samples: u64) {
        Metrics::inc(&self.metrics.engine_calls);
        Metrics::add(&self.metrics.gated_adds, out.gated_adds);
        Metrics::add(&self.metrics.executed_adds, out.executed_adds);
        Metrics::add(&self.metrics.backend_ns, out.backend_ns);
        Metrics::add(&self.metrics.samples_paid, samples);
    }
}

/// Fraction of frame elements whose bit pattern moved (exact, NaN-safe
/// compare) — the registry-level change measure; the backend's own
/// quantized diff may find even fewer changed pixels.
fn changed_fraction(old: &[f32], new: &[f32]) -> f64 {
    if old.len() != new.len() || new.is_empty() {
        return 1.0;
    }
    let changed = old.iter().zip(new).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
    changed as f64 / new.len() as f64
}

fn argmax_conf(p: &[f32]) -> (usize, f32) {
    let mut best = 0usize;
    for (i, v) in p.iter().enumerate() {
        if *v > p.get(best).copied().unwrap_or(f32::NEG_INFINITY) {
            best = i;
        }
    }
    (best, p.get(best).copied().unwrap_or(0.0))
}
