//! Serving metrics: lock-free counters + a coarse latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two microsecond buckets: [..1us, ..2us, ..4us, ...], 32 of them.
const BUCKETS: usize = 32;

#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Upper bound of the bucket containing quantile `q` (0..1).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(u64::MAX >> 20)
    }
}

/// Bounded ring of recent named failures.  A cascade (breaker trip,
/// repeated resurrections, a dying backend) is diagnosable post-mortem
/// from the last [`ErrorRing::CAP`] messages, not just the final one;
/// `total` keeps counting past the bound.
#[derive(Debug, Default)]
pub struct ErrorRing {
    ring: std::sync::Mutex<std::collections::VecDeque<String>>,
    total: AtomicU64,
}

impl ErrorRing {
    /// Messages retained; older ones fall off the front.
    pub const CAP: usize = 16;

    pub fn push(&self, msg: String) {
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut g = crate::coordinator::lock_unpoisoned(&self.ring);
        while g.len() >= Self::CAP {
            g.pop_front();
        }
        g.push_back(msg);
    }

    /// Most recent message.
    pub fn last(&self) -> Option<String> {
        crate::coordinator::lock_unpoisoned(&self.ring).back().cloned()
    }

    /// Retained messages, oldest first.
    pub fn to_vec(&self) -> Vec<String> {
        crate::coordinator::lock_unpoisoned(&self.ring).iter().cloned().collect()
    }

    /// Every failure ever pushed (including those the ring dropped).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }
}

/// Coordinator-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub escalated: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    pub engine_calls: AtomicU64,
    pub latency: Histogram,
    pub stage1_latency: Histogram,
    /// Time each request spent in the admission queue before its batch
    /// formed (recorded for served *and* shed requests — shed requests
    /// are billed zero backend work but their wait is real).
    pub queue_wait: Histogram,
    pub gated_adds: AtomicU64,
    /// Accumulator adds the backends actually *executed* (session caches
    /// and the IntKernel O(Δ) delta path shrink it below the charge) —
    /// real work, not hardware-model accounting.
    pub executed_adds: AtomicU64,
    /// Backend-measured wall time across all engine passes, in ns.
    pub backend_ns: AtomicU64,
    /// Per-weight samples actually paid for (stage-1 `n_low` per row
    /// plus the incremental `n_high − n_low` per escalated row).
    pub samples_paid: AtomicU64,
    /// Samples carried over from stage 1 into an escalation instead of
    /// being recomputed — the progressive-refinement win (Sec. 4.5).
    pub samples_reused: AtomicU64,
    /// Engine/backend failures observed by the stage handlers (each
    /// affected request receives a named error reply; see
    /// [`Self::recent_errors`] for the root causes).
    pub engine_errors: AtomicU64,
    /// Recent engine-failure root causes, oldest first (bounded).
    pub recent: ErrorRing,
    /// Faults the supervisor observed (injected or organic), mirrored
    /// from [`crate::coordinator::supervisor::SupervisorStats`].
    pub faults_seen: AtomicU64,
    /// Supervised op retries (same op re-submitted after a transient
    /// fault).
    pub retries: AtomicU64,
    /// Sessions rebuilt bit-identically from recorded provenance.
    pub resurrections: AtomicU64,
    /// Replies served degraded (retained stage-1 answer after recovery
    /// was exhausted or the breaker was open).
    pub degraded: AtomicU64,
    /// Circuit-breaker transitions into `Open`.
    pub breaker_trips: AtomicU64,
    /// Stage-1 sessions currently resident in the engine's pool (gauge,
    /// mirrored from [`crate::coordinator::engine::EngineStats`]).
    pub pool_sessions: AtomicU64,
    /// High-water mark of resident pool sessions.
    pub pool_peak: AtomicU64,
    /// Pool sessions evicted by the LRU bound.
    pub pool_evictions: AtomicU64,
    /// Merged dispatches performed (escalation groups coalesced).
    pub merges: AtomicU64,
    /// Backend dispatches (padded artifact runs, on stateless backends)
    /// saved by merging.
    pub runs_saved: AtomicU64,
    /// Streaming frames served through session rebase (mirrored from
    /// [`crate::coordinator::engine::EngineStats`]).
    pub stream_frames: AtomicU64,
    /// Input-frame elements observed unchanged across rebases (proxy
    /// for the accumulator rows the backend reused).
    pub stream_rows_reused: AtomicU64,
    /// Σ per-frame changed fraction in milli-units; the mean rebase
    /// fraction is `stream_frac_milli / stream_frames`.
    pub stream_frac_milli: AtomicU64,
    /// Requests refused or dropped by the overload layer with a named
    /// `(overloaded)` error: admission-queue-full refusals, brownout
    /// shedding, and deadline sheds at dequeue.  Every shed request
    /// still receives its error reply — shed ≠ lost.
    pub shed: AtomicU64,
    /// Queued stream frames dropped latest-frame-wins under brownout
    /// (the superseded frame's caller gets a named error).
    pub frames_coalesced: AtomicU64,
    /// Current brownout ladder rung (gauge): 0 full, 1 cap-escalation,
    /// 2 stage1-only, 3 shed.
    pub brownout_level: AtomicU64,
    /// New streams bounced off a fully-pinned session pool (mirrored
    /// from [`crate::coordinator::engine::EngineStats`]).
    pub pool_bounces: AtomicU64,
    /// `(overloaded)` faults the supervisor saw — counted, retryable,
    /// and never fed to the circuit breaker (mirrored from
    /// [`crate::coordinator::supervisor::SupervisorStats`]).
    pub overloaded: AtomicU64,
    /// Outputs served through the IntKernel's scalar contraction
    /// (mirrored from [`crate::coordinator::engine::EngineStats`]).
    pub kernel_scalar: AtomicU64,
    /// Outputs served through the word-at-a-time packed contraction.
    pub kernel_packed: AtomicU64,
    /// Outputs served through the multi-word blocked contraction.
    pub kernel_blocked: AtomicU64,
    /// Outputs whose pass took the im2col-free direct convolution walk
    /// for at least one layer.  Backends that do not tag their passes
    /// (the exact sim, PJRT artifacts) are the remainder of `completed`
    /// outside these four counters.
    pub kernel_direct: AtomicU64,
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Record an engine failure: bump the counter and ring the message.
    pub fn record_engine_error(&self, err: &anyhow::Error) {
        Self::inc(&self.engine_errors);
        self.recent.push(format!("{err:#}"));
    }

    /// Root cause of the most recent engine failure.
    pub fn last_engine_error(&self) -> Option<String> {
        self.recent.last()
    }

    /// Recent engine-failure root causes, oldest first (bounded ring).
    pub fn recent_errors(&self) -> Vec<String> {
        self.recent.to_vec()
    }

    /// Mirror the supervisor's recovery counters into the serving
    /// metrics.
    pub fn sync_supervisor(&self, stats: &crate::coordinator::supervisor::SupervisorStats) {
        use std::sync::atomic::Ordering::Relaxed;
        self.faults_seen.store(stats.faults_seen.load(Relaxed), Relaxed);
        self.retries.store(stats.retries.load(Relaxed), Relaxed);
        self.resurrections.store(stats.resurrections.load(Relaxed), Relaxed);
        self.degraded.store(stats.degraded.load(Relaxed), Relaxed);
        self.breaker_trips.store(stats.breaker_trips.load(Relaxed), Relaxed);
        self.overloaded.store(stats.overloaded.load(Relaxed), Relaxed);
    }

    /// Mirror the engine's live pool/merge counters into the serving
    /// metrics (called by the stage handlers after each engine pass).
    pub fn sync_engine(&self, stats: &crate::coordinator::engine::EngineStats) {
        use std::sync::atomic::Ordering::Relaxed;
        self.pool_sessions.store(stats.sessions_open.load(Relaxed), Relaxed);
        self.pool_peak.store(stats.sessions_peak.load(Relaxed), Relaxed);
        self.pool_evictions.store(stats.evictions.load(Relaxed), Relaxed);
        self.merges.store(stats.merges.load(Relaxed), Relaxed);
        self.runs_saved.store(stats.runs_saved.load(Relaxed), Relaxed);
        self.stream_frames.store(stats.stream_frames.load(Relaxed), Relaxed);
        self.stream_rows_reused.store(stats.stream_rows_reused.load(Relaxed), Relaxed);
        self.stream_frac_milli.store(stats.stream_frac_milli.load(Relaxed), Relaxed);
        self.pool_bounces.store(stats.pool_bounces.load(Relaxed), Relaxed);
        self.kernel_scalar.store(stats.kernel_scalar.load(Relaxed), Relaxed);
        self.kernel_packed.store(stats.kernel_packed.load(Relaxed), Relaxed);
        self.kernel_blocked.store(stats.kernel_blocked.load(Relaxed), Relaxed);
        self.kernel_direct.store(stats.kernel_direct.load(Relaxed), Relaxed);
    }

    /// Mean fraction of each served frame that actually changed (0..1);
    /// zero before any stream traffic.
    pub fn stream_mean_frac(&self) -> f64 {
        let frames = self.stream_frames.load(Ordering::Relaxed);
        if frames == 0 {
            return 0.0;
        }
        self.stream_frac_milli.load(Ordering::Relaxed) as f64 / (1000.0 * frames as f64)
    }

    /// Mean rows per dispatched batch (occupancy diagnostics).
    pub fn batch_occupancy(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_rows.load(Ordering::Relaxed) as f64 / batches as f64
    }

    pub fn escalation_rate(&self) -> f64 {
        let c = self.completed.load(Ordering::Relaxed).max(1);
        self.escalated.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Fraction of the naive two-pass sample budget that progressive
    /// refinement avoided: `reused / (paid + reused)`.  Zero under flat
    /// serving; approaches `n_low / (n_low + n_high)` when every request
    /// escalates.
    pub fn reuse_ratio(&self) -> f64 {
        let reused = self.samples_reused.load(Ordering::Relaxed) as f64;
        let paid = self.samples_paid.load(Ordering::Relaxed) as f64;
        if reused + paid == 0.0 {
            return 0.0;
        }
        reused / (paid + reused)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} completed={} escalated={:.1}% occupancy={:.2} reuse={:.1}% \
             pool={}(peak {}, evicted {}) merges={} runs_saved={} \
             kernel=scalar:{},packed:{},blocked:{},direct:{} \
             stream={} frames(rows_reused {}, mean_frac {:.3}) \
             exec_adds={} backend_ms={:.1} \
             faults={} retries={} resurrections={} degraded={} breaker_trips={} errors={} \
             shed={} coalesced={} bounced={} overloaded={} brownout={} \
             p50={:?} p99={:?} mean={:?} qwait_p50={:?} qwait_p99={:?}",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            100.0 * self.escalation_rate(),
            self.batch_occupancy(),
            100.0 * self.reuse_ratio(),
            self.pool_sessions.load(Ordering::Relaxed),
            self.pool_peak.load(Ordering::Relaxed),
            self.pool_evictions.load(Ordering::Relaxed),
            self.merges.load(Ordering::Relaxed),
            self.runs_saved.load(Ordering::Relaxed),
            self.kernel_scalar.load(Ordering::Relaxed),
            self.kernel_packed.load(Ordering::Relaxed),
            self.kernel_blocked.load(Ordering::Relaxed),
            self.kernel_direct.load(Ordering::Relaxed),
            self.stream_frames.load(Ordering::Relaxed),
            self.stream_rows_reused.load(Ordering::Relaxed),
            self.stream_mean_frac(),
            self.executed_adds.load(Ordering::Relaxed),
            self.backend_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.faults_seen.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.resurrections.load(Ordering::Relaxed),
            self.degraded.load(Ordering::Relaxed),
            self.breaker_trips.load(Ordering::Relaxed),
            self.engine_errors.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.frames_coalesced.load(Ordering::Relaxed),
            self.pool_bounces.load(Ordering::Relaxed),
            self.overloaded.load(Ordering::Relaxed),
            crate::coordinator::overload::BrownoutLevel::from_u8(
                self.brownout_level.load(Ordering::Relaxed).min(3) as u8,
            )
            .as_str(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.latency.mean(),
            self.queue_wait.quantile(0.5),
            self.queue_wait.quantile(0.99),
        );
        let recent = self.recent.to_vec();
        if !recent.is_empty() {
            s.push_str(&format!(" recent_errors[{}]: {}", recent.len(), recent.join(" | ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for us in [10u64, 20, 40, 80, 1000, 2000, 50_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) >= Duration::from_micros(32_768));
    }

    #[test]
    fn mean_is_sane() {
        let h = Histogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
    }

    #[test]
    fn occupancy() {
        let m = Metrics::default();
        Metrics::add(&m.batches, 2);
        Metrics::add(&m.batched_rows, 12);
        assert!((m.batch_occupancy() - 6.0).abs() < 1e-9);
    }

    /// Identical counter histories must render the identical summary
    /// string — the textual face of the determinism invariant (psb-lint
    /// bans unordered maps and clocks from everything feeding it).
    #[test]
    fn summary_text_is_stable_across_runs() {
        let build = || {
            let m = Metrics::default();
            Metrics::add(&m.requests, 100);
            Metrics::add(&m.completed, 100);
            Metrics::add(&m.escalated, 35);
            Metrics::add(&m.batches, 20);
            Metrics::add(&m.batched_rows, 100);
            Metrics::add(&m.samples_paid, 1000);
            Metrics::add(&m.samples_reused, 280);
            Metrics::add(&m.executed_adds, 123_456);
            Metrics::add(&m.backend_ns, 5_000_000);
            Metrics::add(&m.pool_sessions, 3);
            Metrics::add(&m.pool_peak, 7);
            Metrics::add(&m.merges, 4);
            Metrics::add(&m.kernel_packed, 60);
            Metrics::add(&m.kernel_blocked, 30);
            Metrics::add(&m.kernel_direct, 10);
            m.latency.record(Duration::from_micros(300));
            m.latency.record(Duration::from_micros(900));
            m.summary()
        };
        let a = build();
        assert_eq!(a, build());
        assert!(a.contains("requests=100"), "{a}");
        assert!(a.contains("kernel=scalar:0,packed:60,blocked:30,direct:10"), "{a}");
    }

    #[test]
    fn error_ring_is_bounded_and_ordered() {
        let m = Metrics::default();
        for i in 0..20 {
            m.record_engine_error(&anyhow::anyhow!("boom {i}"));
        }
        let recent = m.recent_errors();
        assert_eq!(recent.len(), ErrorRing::CAP, "ring holds the newest CAP messages");
        assert_eq!(recent.first().map(String::as_str), Some("boom 4"), "oldest first");
        assert_eq!(m.last_engine_error().as_deref(), Some("boom 19"));
        assert_eq!(m.engine_errors.load(Ordering::Relaxed), 20, "counter outlives the ring");
        assert_eq!(m.recent.total(), 20);
        let s = m.summary();
        assert!(s.contains("recent_errors[16]: boom 4 | "), "{s}");
    }

    #[test]
    fn summary_names_the_overload_fields() {
        let m = Metrics::default();
        Metrics::add(&m.shed, 3);
        Metrics::add(&m.frames_coalesced, 2);
        Metrics::add(&m.pool_bounces, 1);
        Metrics::add(&m.brownout_level, 2);
        m.queue_wait.record(Duration::from_micros(500));
        let s = m.summary();
        assert!(s.contains("shed=3"), "{s}");
        assert!(s.contains("coalesced=2"), "{s}");
        assert!(s.contains("bounced=1"), "{s}");
        assert!(s.contains("brownout=stage1-only"), "{s}");
        assert!(s.contains("qwait_p50="), "{s}");
        assert!(s.contains("kernel=scalar:0,packed:0,blocked:0,direct:0"), "{s}");
    }

    #[test]
    fn reuse_ratio_bounds() {
        let m = Metrics::default();
        assert_eq!(m.reuse_ratio(), 0.0, "no traffic -> no reuse");
        // one request at n_low=8 escalated to 16: paid 8 + 8, reused 8
        Metrics::add(&m.samples_paid, 16);
        Metrics::add(&m.samples_reused, 8);
        assert!((m.reuse_ratio() - 8.0 / 24.0).abs() < 1e-9);
    }
}
