//! The serving supervisor: deadlines, bounded retries, bit-identical
//! session resurrection, and a per-engine circuit breaker.
//!
//! PSB sessions are a *pure function* of `(plan, seed, input)`: a
//! `begin` replayed under the same triple reproduces the session's
//! logits and exact per-row charges bit-identically, `narrow + refine`
//! replayed on top reproduces the escalation, and
//! `rebase_input(x)` ≡ a fresh `begin(x, seed)` at the current plan
//! (the streaming contract).  That determinism is the whole recovery
//! story — a killed, evicted, poisoned, or panicked session is not lost
//! state, just lost *time*, and the supervisor rebuilds it from recorded
//! provenance and replays the op:
//!
//! * **`Begin`** is stateless from the caller's view: transient faults
//!   retry the job directly under a deadline budget with deterministic
//!   exponential backoff.
//! * **`Refine`** consumes its session on failure, so a transient fault
//!   triggers **resurrection**: replay `begin(plan, x, batch, seed)`
//!   from provenance, re-narrow to the same rows, re-refine to the same
//!   target — the reply is bit-identical to the never-faulted pass
//!   (asserted against an oracle in `rust/tests/chaos.rs`).
//! * **`SubmitFrame`** resurrects through the rebase contract itself: a
//!   fresh `begin` on the *new* frame under the stream's seed is
//!   bit-identical (logits and billing) to the rebase that failed.
//! * Errors marked `(permanent)` never burn retries; the caller
//!   degrades (escalations fall back to their retained stage-1 answer)
//!   or resurrects fresh (streams).
//!
//! The **circuit breaker** guards the escalation path: after
//! [`SupervisorConfig::breaker_threshold`] consecutive supervised-op
//! failures it opens, refusing `refine`/`fork_escalate` outright — the
//! paper's progressive ladder means every request still holds a valid
//! stage-1 answer, so an open breaker degrades precision, not
//! availability.  After a cooldown it half-opens; the next escalation
//! runs as a probe and its outcome closes or re-opens the breaker.
//! Begins and frames are never gated — they *are* the probe traffic
//! that restores service.
//!
//! All timing (deadlines, backoff, cooldown) goes through
//! [`crate::coordinator::clock::Clock`], so chaos tests drive the whole
//! recovery machinery on a virtual clock without real sleeps.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::clock::Clock;
use crate::coordinator::engine::{Engine, EngineJob, EngineOutput, SessionId};
use crate::coordinator::lock_unpoisoned;
use crate::coordinator::overload::is_overloaded;
use crate::precision::PrecisionPlan;

/// Recovery-policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct SupervisorConfig {
    /// Per-job wall budget: retries and resurrections stop when a job
    /// has been in flight this long (measured on the supervisor clock).
    pub deadline: Duration,
    /// Most retries (re-submissions after the first attempt) per job.
    pub max_retries: u32,
    /// Backoff before retry `k` is `backoff_base · 2^(k−1)` —
    /// deterministic, no jitter: reproducibility outranks thundering
    /// herds on a single-process engine.
    pub backoff_base: Duration,
    /// Consecutive supervised-op failures that open the breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker refuses escalations before half-opening
    /// for a probe.
    pub breaker_cooldown: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            deadline: Duration::from_secs(2),
            max_retries: 3,
            backoff_base: Duration::from_millis(5),
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(250),
        }
    }
}

/// Circuit-breaker position (see module docs for the transitions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal service.
    Closed,
    /// Escalations refused; stage-1 answers serve degraded.
    Open,
    /// Cooldown elapsed: the next escalation runs as a probe.
    HalfOpen,
}

/// Recovery counters (mirrored into `Metrics` by the stage handlers).
#[derive(Debug, Default)]
pub struct SupervisorStats {
    /// Supervised-op failures observed (injected or organic), including
    /// wrong-geometry replies.
    pub faults_seen: AtomicU64,
    /// Ops re-submitted after a transient fault.
    pub retries: AtomicU64,
    /// Sessions rebuilt bit-identically from provenance.
    pub resurrections: AtomicU64,
    /// Replies the caller served degraded (retained stage-1 answer);
    /// bumped by the stage handlers, not the supervisor.
    pub degraded: AtomicU64,
    /// Breaker transitions into [`BreakerState::Open`].
    pub breaker_trips: AtomicU64,
    /// Faults named `(overloaded)` — capacity refusals.  Counted here
    /// but never fed to the breaker: load is the brownout controller's
    /// problem, not a backend-health signal.
    pub overloaded: AtomicU64,
}

/// What it takes to rebuild a session bit-identically: the `begin`
/// triple.  `narrow`/`refine` are replayed by the op that needs them
/// (their arguments travel with the job), and a rebased stream session's
/// identity is just this record with `x` advanced to the latest frame.
#[derive(Clone)]
struct Provenance {
    plan: PrecisionPlan,
    x: Vec<f32>,
    batch: usize,
    seed: u64,
}

/// Most begin records retained for resurrection; ids are monotonic, so
/// overflow evicts the oldest sessions first.
const PROVENANCE_CAP: usize = 256;

struct BreakerInner {
    state: BreakerState,
    consecutive: u32,
    opened_at: Duration,
}

/// An in-flight supervised refine: created by
/// [`Supervisor::submit_refine`] (so a window of escalation groups hits
/// the engine together and can merge), resolved by
/// [`Supervisor::await_refine`] (which owns the retry/resurrection
/// loop).
pub struct RefineTicket {
    session: SessionId,
    rows: Vec<usize>,
    plan: PrecisionPlan,
    rx: Option<mpsc::Receiver<Result<EngineOutput>>>,
    start: Duration,
}

/// Deadline/retry/resurrection/breaker supervision over one [`Engine`].
pub struct Supervisor {
    engine: Arc<Engine>,
    clock: Clock,
    cfg: SupervisorConfig,
    /// Output classes — every supervised reply's logits must be
    /// `expected_rows × num_classes` (wrong-geometry replies are faults).
    num_classes: usize,
    stats: Arc<SupervisorStats>,
    provenance: Mutex<BTreeMap<SessionId, Provenance>>,
    breaker: Mutex<BreakerInner>,
}

/// `true` when the failure is marked non-retryable by its producer.
fn is_permanent(msg: &str) -> bool {
    msg.contains("(permanent)")
}

impl Supervisor {
    pub fn new(
        engine: Arc<Engine>,
        clock: Clock,
        cfg: SupervisorConfig,
        num_classes: usize,
    ) -> Supervisor {
        Supervisor {
            engine,
            clock,
            cfg,
            num_classes,
            stats: Arc::new(SupervisorStats::default()),
            provenance: Mutex::new(BTreeMap::new()),
            breaker: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive: 0,
                opened_at: Duration::ZERO,
            }),
        }
    }

    pub fn stats(&self) -> &SupervisorStats {
        &self.stats
    }

    /// Current breaker position (resolves an elapsed cooldown to
    /// `HalfOpen` without consuming the probe).
    pub fn breaker_state(&self) -> BreakerState {
        let b = lock_unpoisoned(&self.breaker);
        match b.state {
            BreakerState::Open
                if self.clock.now().saturating_sub(b.opened_at) >= self.cfg.breaker_cooldown =>
            {
                BreakerState::HalfOpen
            }
            s => s,
        }
    }

    /// May an escalation run right now?  Open → no (degrade); an elapsed
    /// cooldown half-opens and admits this call as the probe.
    fn breaker_allows(&self) -> bool {
        let mut b = lock_unpoisoned(&self.breaker);
        match b.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                if self.clock.now().saturating_sub(b.opened_at) >= self.cfg.breaker_cooldown {
                    b.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    fn breaker_success(&self) {
        let mut b = lock_unpoisoned(&self.breaker);
        b.consecutive = 0;
        b.state = BreakerState::Closed;
    }

    fn breaker_failure(&self) {
        let mut b = lock_unpoisoned(&self.breaker);
        b.consecutive += 1;
        let trip = match b.state {
            BreakerState::HalfOpen => true, // failed probe re-opens
            BreakerState::Closed => b.consecutive >= self.cfg.breaker_threshold,
            BreakerState::Open => false,
        };
        if trip {
            b.state = BreakerState::Open;
            b.opened_at = self.clock.now();
            self.stats.breaker_trips.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a fault: counters + breaker.  Faults named `(overloaded)`
    /// are load, not ill health — they bump their own counter and skip
    /// the breaker, so a saturated admission queue cannot trip the
    /// escalation path open (the brownout ladder owns the load
    /// response; the breaker models backend health).
    fn note_fault(&self, msg: &str) {
        self.stats.faults_seen.fetch_add(1, Ordering::Relaxed);
        if is_overloaded(msg) {
            self.stats.overloaded.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.breaker_failure();
    }

    /// Deterministic exponential backoff before retry `attempt` (1-based).
    fn backoff(&self, attempt: u32) {
        let exp = attempt.saturating_sub(1).min(16);
        self.clock.sleep(self.cfg.backoff_base.saturating_mul(1u32 << exp));
    }

    fn over_budget(&self, start: Duration) -> bool {
        self.clock.now().saturating_sub(start) >= self.cfg.deadline
    }

    /// Logits of a supervised reply must cover `rows × num_classes`; a
    /// backend that answers with the wrong geometry has faulted even
    /// though it "succeeded".  `rows = None` checks divisibility only.
    fn check_geometry(&self, out: &EngineOutput, rows: Option<usize>) -> Result<()> {
        let nc = self.num_classes;
        if nc == 0 {
            return Ok(());
        }
        let n = out.exec.logits.len();
        match rows {
            Some(r) => anyhow::ensure!(
                n == r * nc,
                "wrong output geometry: {n} logits for {r} rows × {nc} classes (transient)"
            ),
            None => anyhow::ensure!(
                n > 0 && n % nc == 0,
                "wrong output geometry: {n} logits is not a row multiple of {nc} classes (transient)"
            ),
        }
        Ok(())
    }

    fn remember(&self, id: SessionId, prov: Provenance) {
        let mut map = lock_unpoisoned(&self.provenance);
        map.insert(id, prov);
        while map.len() > PROVENANCE_CAP {
            let Some((&oldest, _)) = map.iter().next() else { break };
            map.remove(&oldest);
        }
    }

    fn recall(&self, id: SessionId) -> Option<Provenance> {
        lock_unpoisoned(&self.provenance).get(&id).cloned()
    }

    fn forget(&self, id: SessionId) {
        lock_unpoisoned(&self.provenance).remove(&id);
    }

    /// Close a supervised session and drop its provenance record.
    pub fn close_session(&self, id: SessionId) -> Result<()> {
        self.forget(id);
        self.engine.close_session(id)
    }

    /// Supervised stage-1 pass: begin a kept session under a deadline
    /// budget with bounded, backed-off retries (a begin is stateless
    /// from the caller's view, so retry is plain re-submission).
    /// Records the session's provenance for later resurrection.  Returns
    /// the output and whether recovery was needed (`recovered == true` ⇒
    /// at least one retry happened; the logits are still bit-identical
    /// to a first-try pass, which the chaos suite asserts).
    pub fn begin_session(
        &self,
        plan: PrecisionPlan,
        x: Vec<f32>,
        batch: usize,
        seed: u64,
    ) -> Result<(EngineOutput, bool)> {
        let start = self.clock.now();
        let mut attempt = 0u32;
        loop {
            let fault = match self.engine.begin_session(plan.clone(), x.clone(), batch, seed) {
                Ok(out) => match self.check_geometry(&out, Some(batch)) {
                    Ok(()) => {
                        if let Some(id) = out.session {
                            self.remember(
                                id,
                                Provenance { plan, x, batch, seed },
                            );
                        }
                        self.breaker_success();
                        return Ok((out, attempt > 0));
                    }
                    Err(geom) => {
                        // the kept session may carry the same garbling —
                        // drop it rather than let an escalation find it
                        if let Some(id) = out.session {
                            let _ = self.engine.close_session(id);
                        }
                        geom
                    }
                },
                Err(e) => e,
            };
            let msg = format!("{fault:#}");
            self.note_fault(&msg);
            if is_permanent(&msg) || attempt >= self.cfg.max_retries || self.over_budget(start) {
                return Err(anyhow!(
                    "supervised begin failed after {} attempt(s): {msg}",
                    attempt + 1
                ));
            }
            attempt += 1;
            self.stats.retries.fetch_add(1, Ordering::Relaxed);
            self.backoff(attempt);
        }
    }

    /// Phase 1 of a supervised escalation: breaker-check, then submit
    /// the narrow+refine job *without waiting*.  Callers submit every
    /// queued group before awaiting any (see `server::handle_stage2`),
    /// which is what lets the engine's dispatch window merge compatible
    /// groups — supervision must not cost that.
    pub fn submit_refine(
        &self,
        session: SessionId,
        rows: Vec<usize>,
        plan: PrecisionPlan,
    ) -> Result<RefineTicket> {
        anyhow::ensure!(
            self.breaker_allows(),
            "circuit breaker open: escalation refused, serve the stage-1 answer"
        );
        let (reply, rx) = mpsc::sync_channel(1);
        self.engine.submit(EngineJob::Refine {
            session,
            rows: Some(rows.clone()),
            plan: plan.clone(),
            keep: false,
            reply,
        })?;
        Ok(RefineTicket { session, rows, plan, rx: Some(rx), start: self.clock.now() })
    }

    /// Phase 2: wait for a ticket's reply; on transient failure (the
    /// refine consumed its session) resurrect from provenance — replay
    /// `begin`, re-narrow, re-refine — within the deadline budget.
    /// Returns the output plus whether resurrection happened.
    pub fn await_refine(&self, mut ticket: RefineTicket) -> Result<(EngineOutput, bool)> {
        let mut attempt = 0u32;
        let mut resurrected = false;
        let mut session = ticket.session;
        loop {
            // ensure a refine is in flight (retries land here with none)
            let rx = match ticket.rx.take() {
                Some(rx) => rx,
                None => {
                    let (reply, rx) = mpsc::sync_channel(1);
                    self.engine.submit(EngineJob::Refine {
                        session,
                        rows: Some(ticket.rows.clone()),
                        plan: ticket.plan.clone(),
                        keep: false,
                        reply,
                    })?;
                    rx
                }
            };
            let fault = match rx.recv() {
                Ok(Ok(out)) => match self.check_geometry(&out, Some(ticket.rows.len())) {
                    Ok(()) => {
                        self.forget(session);
                        self.breaker_success();
                        return Ok((out, resurrected));
                    }
                    Err(geom) => geom,
                },
                Ok(Err(e)) => e,
                Err(_) => anyhow!("engine dropped the escalation job"),
            };
            let msg = format!("{fault:#}");
            self.note_fault(&msg);
            if is_permanent(&msg) || attempt >= self.cfg.max_retries || self.over_budget(ticket.start)
            {
                return Err(anyhow!(
                    "supervised refine failed after {} attempt(s): {msg}",
                    attempt + 1
                ));
            }
            attempt += 1;
            self.stats.retries.fetch_add(1, Ordering::Relaxed);
            self.backoff(attempt);
            // the failed refine consumed the session: resurrect it from
            // provenance under its original (plan, x, batch, seed) so the
            // replayed narrow+refine is bit-identical to the lost pass
            let Some(prov) = self.recall(session) else {
                return Err(anyhow!(
                    "supervised refine failed and session {session} has no provenance \
                     to resurrect from: {msg}"
                ));
            };
            match self.engine.begin_session(prov.plan.clone(), prov.x.clone(), prov.batch, prov.seed)
            {
                Ok(out) => {
                    let Some(new_id) = out.session else {
                        return Err(anyhow!("resurrection begin returned no session handle"));
                    };
                    self.forget(session);
                    self.remember(new_id, prov);
                    self.stats.resurrections.fetch_add(1, Ordering::Relaxed);
                    resurrected = true;
                    session = new_id;
                    // loop resubmits the refine against the new session
                }
                Err(e) => {
                    // the resurrection itself faulted; account it and let
                    // the loop retry the whole recovery within budget
                    self.note_fault(&format!("{e:#}"));
                }
            }
        }
    }

    /// Supervised streaming frame: rebase the pinned session; on
    /// failure, resurrect through the rebase contract — a fresh kept
    /// `begin` on the *new* frame under the stream's recorded
    /// `(plan, seed)` is bit-identical (logits and billing) to the
    /// rebase that failed.  The resurrected session is pinned in place
    /// of the lost one and the reply carries its id.
    pub fn submit_frame(&self, session: SessionId, x: Vec<f32>) -> Result<(EngineOutput, bool)> {
        let start = self.clock.now();
        let mut attempt = 0u32;
        let mut recovered = false;
        let mut session = session;
        loop {
            let prov_batch = self.recall(session).map(|p| p.batch);
            let fault = match self.engine.submit_frame(session, x.clone()) {
                Ok(out) => match self.check_geometry(&out, prov_batch) {
                    Ok(()) => {
                        // the session's identity advanced to this frame
                        if let Some(mut prov) = self.recall(session) {
                            prov.x = x;
                            self.remember(session, prov);
                        }
                        self.breaker_success();
                        return Ok((out, recovered));
                    }
                    Err(geom) => geom,
                },
                Err(e) => e,
            };
            let msg = format!("{fault:#}");
            self.note_fault(&msg);
            if attempt >= self.cfg.max_retries || self.over_budget(start) {
                return Err(anyhow!(
                    "supervised frame failed after {} attempt(s): {msg}",
                    attempt + 1
                ));
            }
            attempt += 1;
            self.stats.retries.fetch_add(1, Ordering::Relaxed);
            self.backoff(attempt);
            // Resurrect (permanent faults included — a fresh session is a
            // different op): begin on the new frame at the stream's
            // recorded plan + seed, pin it, and retire the old id.
            let Some(prov) = self.recall(session) else {
                return Err(anyhow!(
                    "supervised frame failed and session {session} has no provenance \
                     to resurrect from: {msg}"
                ));
            };
            match self.engine.begin_session(prov.plan.clone(), x.clone(), prov.batch, prov.seed) {
                Ok(out) => match (out.session, self.check_geometry(&out, Some(prov.batch))) {
                    (Some(new_id), Ok(())) => {
                        let _ = self.engine.pin_session(new_id, true);
                        let _ = self.engine.pin_session(session, false);
                        let _ = self.engine.close_session(session);
                        self.forget(session);
                        self.remember(
                            new_id,
                            Provenance { x: x.clone(), ..prov },
                        );
                        self.stats.resurrections.fetch_add(1, Ordering::Relaxed);
                        recovered = true;
                        self.breaker_success();
                        // the begin IS the frame's answer (rebase ≡ fresh
                        // begin, bit-identically)
                        return Ok((out, recovered));
                    }
                    (Some(new_id), Err(geom)) => {
                        // garbled resurrection output: the session state
                        // is fine but the reply is not — drop it and let
                        // the loop try again
                        let _ = self.engine.close_session(new_id);
                        self.note_fault(&format!("{geom:#}"));
                    }
                    (None, _) => {
                        return Err(anyhow!("resurrection begin returned no session handle"));
                    }
                },
                Err(e) => {
                    self.note_fault(&format!("{e:#}"));
                }
            }
        }
    }

    /// Supervised stream escalation: refine a *fork* of the pinned
    /// session.  Breaker-gated like any escalation; retried directly
    /// (the pinned session is untouched by a failed fork), never
    /// resurrected — on exhaustion the caller serves the rebased
    /// stage-1 answer as `Degraded`, and a poisoned pinned session gets
    /// resurrected by the *next frame's* rebase path.
    pub fn fork_escalate(
        &self,
        session: SessionId,
        rows: Option<Vec<usize>>,
        plan: PrecisionPlan,
    ) -> Result<(EngineOutput, bool)> {
        anyhow::ensure!(
            self.breaker_allows(),
            "circuit breaker open: stream escalation refused, serve the rebased answer"
        );
        let start = self.clock.now();
        let mut attempt = 0u32;
        let expected = rows.as_ref().map(|r| r.len()).or_else(|| {
            self.recall(session).map(|p| p.batch)
        });
        loop {
            let fault = match self.engine.fork_escalate(session, rows.clone(), plan.clone()) {
                Ok(out) => match self.check_geometry(&out, expected) {
                    Ok(()) => {
                        self.breaker_success();
                        return Ok((out, attempt > 0));
                    }
                    Err(geom) => geom,
                },
                Err(e) => e,
            };
            let msg = format!("{fault:#}");
            self.note_fault(&msg);
            if is_permanent(&msg) || attempt >= self.cfg.max_retries || self.over_budget(start) {
                return Err(anyhow!(
                    "supervised fork-escalate failed after {} attempt(s): {msg}",
                    attempt + 1
                ));
            }
            attempt += 1;
            self.stats.retries.fetch_add(1, Ordering::Relaxed);
            self.backoff(attempt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permanence_marker_is_textual() {
        assert!(is_permanent("chaos: injected fault #3 on refine (permanent)"));
        assert!(!is_permanent("chaos: injected fault #3 on begin (transient)"));
    }

    #[test]
    fn overload_marker_is_retryable_by_construction() {
        let msg = "engine admission queue full (depth 512, cap 512) (overloaded): retry later";
        assert!(is_overloaded(msg), "capacity refusals carry the overload marker");
        assert!(!is_permanent(msg), "an overloaded refusal must stay retryable");
    }

    #[test]
    fn default_config_is_bounded() {
        let cfg = SupervisorConfig::default();
        assert!(cfg.max_retries >= 1 && cfg.max_retries <= 10);
        assert!(cfg.deadline > cfg.backoff_base * (1 << cfg.max_retries));
        assert!(cfg.breaker_threshold >= 2);
    }
}
