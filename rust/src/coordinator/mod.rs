//! L3 coordinator: an adaptive-precision inference server built on PSB's
//! progressive sampling.
//!
//! The paper's run-time contribution is that precision is a *runtime
//! knob*: the same weights serve any sample size, and capacitor sums are
//! unbiased partial results, so raising the knob only costs the
//! incremental samples.  The coordinator turns that into a serving
//! policy (Sec. 4.5 lifted to the request level):
//!
//! ```text
//! client ── submit ──► [dynamic batcher] ──► engine.begin(plan: n_low) ──► open session
//!                                               │ entropy of last conv
//!                            confident ◄────────┤ (Scheduler: a PrecisionPolicy)
//!                                               ▼ uncertain
//!                      [escalation group] ──► engine.refine(session ∖ rows, plan: n_high)
//! ```
//!
//! * the **engine** serializes model execution on a dedicated thread
//!   over any [`crate::backend::Backend`] — the PJRT runtime over AOT
//!   artifacts ([`Coordinator::start`]) or the pure-rust simulator with
//!   true session-state reuse ([`Coordinator::start_sim`]).  Sessions
//!   (progressive counts + cached per-node accumulators) live in the
//!   engine's bounded **session pool** (several stage-1 sessions in
//!   flight, LRU-evicted) and are escalated by id; compatible
//!   escalation groups drained in one dispatch window **merge** into a
//!   single backend pass (`Backend::merge_sessions`) without touching
//!   any group's capacitor state;
//! * the **batcher** collects requests up to the artifact batch size with
//!   a linger timeout and zero-pads partial batches;
//! * the **scheduler** implements [`crate::precision::PrecisionPolicy`]:
//!   it plans each request's final precision from the mean last-conv
//!   entropy, and the high-entropy fraction escalates by *narrowing and
//!   refining* the stage-1 session — batch-level computational attention
//!   with the network itself as the proposal mechanism;
//! * the **stream registry** serves temporal frame traffic: one pinned
//!   pool session per stream id, *rebased* onto every new frame in
//!   O(changed rows + halo)
//!   ([`crate::backend::InferenceSession::rebase_input`]), with
//!   per-frame fork-escalation — the temporal analog of the spatial
//!   attention above.

// The serving loop reports failure through `Engine::recent_errors` /
// `Metrics::engine_errors` instead of unwinding; psb-lint's no-panic
// rule enforces that lexically, and these scoped clippy lints keep the
// compiler enforcing it too (CI runs clippy with `-D warnings`).
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod clock;
pub mod engine;
pub mod metrics;
pub mod overload;
pub mod scheduler;
pub mod server;
pub mod stream;
pub mod supervisor;

pub use batcher::BatcherConfig;
pub use clock::Clock;
pub use engine::{Engine, EngineConfig, EngineJob, EngineOutput, EngineStats, SessionId};
pub use metrics::Metrics;
pub use overload::{
    bounded_queue, is_overloaded, BrownoutConfig, BrownoutController, BrownoutLevel, LoadSample,
    QueueRx, QueueSendError, QueueTx,
};
pub use scheduler::{EscalationPolicy, SchedulerStats};
pub use server::{ClassifyResponse, Coordinator, CoordinatorConfig, ServedVia};
pub use stream::{StreamConfig, StreamId, StreamRegistry};
pub use supervisor::{BreakerState, Supervisor, SupervisorConfig, SupervisorStats};

/// Lock a mutex, recovering the data of a poisoned lock: the values
/// guarded here (failure strings, scheduler state) stay meaningful after
/// a peer thread's panic, and the serving path must keep reporting
/// errors rather than start unwinding itself.
pub(crate) fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // psb-lint: allow(lock-hygiene): this IS the sanctioned wrapper — the one raw lock every other coordinator lock routes through
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::lock_unpoisoned;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_unpoisoned_recovers_data_after_a_peer_thread_panic() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let _held = lock_unpoisoned(&m2);
            panic!("poison the mutex while holding it");
        });
        assert!(t.join().is_err(), "the peer thread must have panicked");
        assert!(m.is_poisoned(), "the panic-while-held must have poisoned the lock");
        // the guarded data is still meaningful — failure strings and
        // scheduler state must survive a peer's crash
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, vec![1, 2, 3]);
        g.push(4);
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), vec![1, 2, 3, 4], "writes keep working after recovery");
    }
}
