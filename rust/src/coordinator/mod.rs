//! L3 coordinator: an adaptive-precision inference server built on PSB's
//! progressive sampling.
//!
//! The paper's run-time contribution is that precision is a *runtime
//! knob*: the same weights serve any sample size.  The coordinator turns
//! that into a serving policy (Sec. 4.5 lifted to the request level):
//!
//! ```text
//! client ── submit ──► [dynamic batcher] ──► engine(psb @ n_low)
//!                                               │ entropy of last conv
//!                            confident ◄────────┤
//!                                               ▼ uncertain
//!                      [escalation batcher] ──► engine(psb @ n_high)
//! ```
//!
//! * the **engine** owns the PJRT runtime on a dedicated thread (PJRT
//!   handles are not `Send`) and executes one compiled artifact per
//!   `(n, batch)`;
//! * the **batcher** collects requests up to the artifact batch size with
//!   a linger timeout and zero-pads partial batches;
//! * the **scheduler** computes the mean last-conv entropy per request
//!   and escalates the high-entropy fraction to `n_high` — batch-level
//!   computational attention with the network itself as the proposal
//!   mechanism.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use batcher::BatcherConfig;
pub use engine::{Engine, EngineJob};
pub use metrics::Metrics;
pub use scheduler::{EscalationPolicy, SchedulerStats};
pub use server::{ClassifyResponse, Coordinator, CoordinatorConfig};
