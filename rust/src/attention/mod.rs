//! Computational attention (paper Sec. 4.5): use the network itself, in a
//! cheap low-precision mode, to decide where to spend samples.
//!
//! Pipeline (session-native — one [`crate::backend::InferenceSession`]
//! carries the capacitor state through both stages):
//! 1. open a session at a uniform `n_low` plan (8 in the paper) and
//!    `begin` it on the full image;
//! 2. feed the last conv layer's activations to the
//!    [`SpatialAttention`] policy: pixelwise channel entropy
//!    `h_xy = Σ_c −softmax(a_xyc)·log softmax(a_xyc)`, thresholded into
//!    a binary mask of "interesting" regions (~35% of pixels on the
//!    paper's data), upsampled to input resolution;
//! 3. `refine` the *same session* to the resulting spatial plan — masked
//!    regions add only the `n_high − n_low` missing samples (Eq. 8's
//!    additivity), which is the paper's −33% headline.
//!
//! The pipeline is backend-generic: any [`Backend`] whose sessions
//! execute spatial plans can run it — the float simulator *or* the
//! integer shift-add `IntKernel`, whose row-masked contraction turns
//! the masked refine into executed work proportional to the attended
//! fraction (`psb experiment attn --backend int`).

use crate::backend::{Backend, InferenceSession};
use crate::costs::CostCounter;
use crate::precision::{PrecisionPlan, PrecisionPolicy, SpatialAttention};
use crate::sim::tensor::{dims4, Tensor};

/// Pixelwise channel entropy of a feature map `[B,H,W,C] -> [B,H,W]`.
pub fn pixel_entropy(feat: &Tensor) -> Tensor {
    let (b, h, w, c) = dims4(feat);
    let mut out = Tensor::zeros(&[b, h, w]);
    for (pix, row) in feat.data.chunks(c).enumerate() {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &v in row {
            z += (v - max).exp();
        }
        let logz = z.ln() + max;
        let mut hxy = 0.0f32;
        for &v in row {
            let logp = v - logz;
            hxy -= logp.exp() * logp;
        }
        out.data[pix] = hxy;
    }
    out
}

/// How the per-image entropy threshold is chosen.
#[derive(Debug, Clone, Copy)]
pub enum Threshold {
    /// The paper's hard threshold: the image's mean entropy. On our
    /// synthetic data this flags ~50% of pixels (the paper's ImageNet
    /// images yielded ~35%).
    Mean,
    /// Flag only pixels above the q-th entropy quantile (q ∈ (0,1)) —
    /// lets the experiment dial in the paper's 35% region ratio.
    Quantile(f32),
}

/// Per-image mean-threshold mask: pixel is "interesting" iff its entropy
/// exceeds the image's mean entropy (the paper's hard threshold).
pub fn mean_threshold_mask(entropy: &Tensor) -> Vec<bool> {
    threshold_mask(entropy, Threshold::Mean)
}

/// Per-image entropy mask under a [`Threshold`] policy.
pub fn threshold_mask(entropy: &Tensor, thr: Threshold) -> Vec<bool> {
    let b = entropy.shape[0];
    let per = entropy.len() / b;
    let mut mask = vec![false; entropy.len()];
    for bi in 0..b {
        let img = &entropy.data[bi * per..(bi + 1) * per];
        let cut = match thr {
            Threshold::Mean => img.iter().sum::<f32>() / per as f32,
            Threshold::Quantile(q) => {
                let mut sorted: Vec<f32> = img.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let idx = ((per as f32 * q) as usize).min(per - 1);
                sorted[idx]
            }
        };
        for (i, &e) in img.iter().enumerate() {
            mask[bi * per + i] = e > cut;
        }
    }
    mask
}

/// Upsample a `[B,h,w]` mask to `[B,H,W]` (nearest neighbour) — the last
/// conv layer runs at reduced resolution but the spatial plan's mask
/// lives at input resolution.
pub fn upsample_mask(mask: &[bool], b: usize, h: usize, w: usize, th: usize, tw: usize) -> Vec<bool> {
    let mut out = vec![false; b * th * tw];
    for bi in 0..b {
        for y in 0..th {
            let sy = y * h / th;
            for x in 0..tw {
                let sx = x * w / tw;
                out[(bi * th + y) * tw + x] = mask[(bi * h + sy) * w + sx];
            }
        }
    }
    out
}

/// Result of a two-stage adaptive inference.
pub struct AttentionOutput {
    pub logits: Tensor,
    /// Progressive cost: stage 1 plus the *incremental* refinement —
    /// because PSB samples accumulate, low regions keep their `n_low`
    /// result and high regions only add `n_high − n_low` samples.  The
    /// total is exactly `(1−f)·n_low + f·n_high` per MAC (the paper's
    /// −33% at f≈0.35, n_low/n_high = 8/16).
    pub costs: CostCounter,
    /// Non-progressive upper bound: stage 1 + stage 2 recomputed from
    /// scratch (what a quantizer without runtime precision control pays).
    pub costs_two_pass: CostCounter,
    /// Fraction of input pixels flagged interesting (paper: ~0.35).
    pub interesting_fraction: f32,
    /// The stage-1 last-conv feature map (the attention proposal).
    pub stage1_feat: Tensor,
    /// Hardware charge of stage 1 alone.
    pub stage1_costs: CostCounter,
}

/// The full two-stage mechanism of Sec. 4.5 / Table 1 "attention":
/// stage 1 at `n_low` everywhere → entropy mask → progressive refinement
/// of the same session to the `n_low/n_high` spatial split, on any
/// [`Backend`] whose sessions accept spatial plans (sim or IntKernel).
pub fn adaptive_forward(
    backend: &dyn Backend,
    x: &Tensor,
    n_low: u32,
    n_high: u32,
    seed: u64,
) -> AttentionOutput {
    adaptive_forward_with(backend, x, n_low, n_high, seed, Threshold::Mean)
}

/// As [`adaptive_forward`] with an explicit threshold policy.
pub fn adaptive_forward_with(
    backend: &dyn Backend,
    x: &Tensor,
    n_low: u32,
    n_high: u32,
    seed: u64,
    thr: Threshold,
) -> AttentionOutput {
    let (b, h, w, _) = dims4(x);
    let mut sess = backend
        .open(&PrecisionPlan::uniform(n_low))
        .expect("uniform stage-1 plan is always valid");
    let stage1 = sess.begin(x, seed).expect("stage-1 pass over a valid input");
    let feat = sess
        .feat()
        .expect("network must designate a feat node")
        .clone();
    // mask at the *actual* input resolution (the backends are fully
    // convolutional, so x need not match the nominal prepare-time size)
    let mut ctx = backend.plan_context(b);
    ctx.input_hw = (h, w);
    let plan = SpatialAttention { n_low, n_high, threshold: thr }
        .plan(&ctx.with_feat(&feat))
        .expect("feature map provided");
    let interesting = plan.mask_fraction();
    let stage2 = sess
        .refine(&plan)
        .expect("spatial escalation refines the stage-1 plan");
    // progressive total: stage 1 + the incremental escalation.  The
    // gated-add/random-bit fields partition the work exactly; `macs`
    // counts *weight-application coverage* for fp32-baseline comparison
    // and must reflect one logical pass, not one per refinement stage.
    let mut costs = stage1.costs;
    costs.merge(&stage2.costs);
    costs.macs = stage1.costs.macs;
    // non-progressive bound: the fresh spatial pass would re-pay the
    // stage-1 samples on top of the escalation, so two-pass = 2×stage1
    // + incremental (exactly the old recompute-from-scratch accounting)
    let mut costs_two_pass = costs;
    costs_two_pass.merge(&stage1.costs);
    costs_two_pass.macs = stage1.costs.macs;
    AttentionOutput {
        logits: sess.logits().clone(),
        costs,
        costs_two_pass,
        interesting_fraction: interesting,
        stage1_feat: feat,
        stage1_costs: stage1.costs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::precision::PlanContext;
    use crate::rng::Xorshift128Plus;
    use crate::sim::psbnet::{PsbNetwork, PsbOptions};

    #[test]
    fn entropy_flat_vs_peaked() {
        // flat channels -> max entropy; one-hot-ish -> near zero
        let flat = Tensor::from_vec(vec![1.0; 4], &[1, 1, 1, 4]);
        let peaked = Tensor::from_vec(vec![10.0, 0.0, 0.0, 0.0], &[1, 1, 1, 4]);
        let hf = pixel_entropy(&flat).data[0];
        let hp = pixel_entropy(&peaked).data[0];
        assert!((hf - (4.0f32).ln()).abs() < 1e-4, "flat entropy {hf}");
        assert!(hp < 0.01 * hf, "peaked {hp} vs flat {hf}");
    }

    #[test]
    fn mean_threshold_splits_per_image() {
        let e = Tensor::from_vec(vec![0.0, 1.0, 0.0, 1.0, 10.0, 10.0, 10.0, 0.0], &[2, 2, 2]);
        let mask = mean_threshold_mask(&e);
        assert_eq!(&mask[0..4], &[false, true, false, true]);
        assert_eq!(&mask[4..8], &[true, true, true, false]);
    }

    #[test]
    fn upsample_nearest() {
        let mask = vec![true, false, false, true]; // 2x2
        let up = upsample_mask(&mask, 1, 2, 2, 4, 4);
        assert!(up[0] && up[1] && up[4] && up[5]); // top-left quadrant
        assert!(!up[2] && !up[3]); // top-right
        assert!(up[10] && up[15]); // bottom-right
    }

    #[test]
    fn adaptive_costs_sit_between_uniform_levels() {
        let mut rng = Xorshift128Plus::seed_from(2);
        let mut net = crate::models::cnn8(16, &mut rng);
        // settle BN stats
        let d = crate::data::Dataset::synth(&crate::data::SynthConfig {
            train: 64,
            test: 32,
            size: 16,
            ..Default::default()
        });
        for s in 0..4 {
            let (x, _) = d.gather_train(&(0..32).map(|i| i + s).collect::<Vec<_>>());
            net.forward::<Xorshift128Plus>(&x, true, None);
        }
        let backend = SimBackend::new(PsbNetwork::prepare(&net, PsbOptions::default()));
        let (x, _) = d.gather_test(&(0..4).collect::<Vec<_>>());
        let out = adaptive_forward(&backend, &x, 8, 16, 3);
        let flat = |n: u32| {
            let mut s = backend.open(&PrecisionPlan::uniform(n)).unwrap();
            s.begin(&x, 3).unwrap().costs
        };
        let flat8 = flat(8);
        let flat16 = flat(16);
        // progressive accounting: strictly between flat-8 and flat-16
        assert!(out.interesting_fraction > 0.05 && out.interesting_fraction < 0.95);
        assert!(out.costs.gated_adds > flat8.gated_adds);
        assert!(
            out.costs.gated_adds < flat16.gated_adds,
            "{} vs {}",
            out.costs.gated_adds,
            flat16.gated_adds
        );
        // the non-progressive two-pass bound is larger
        assert!(out.costs_two_pass.gated_adds > out.costs.gated_adds);
        assert_eq!(out.logits.shape, vec![4, 10]);
    }

    #[test]
    fn adaptive_logits_match_one_shot_spatial_pass() {
        // the tentpole invariant at the attention level: refining the
        // stage-1 session must equal a fresh pass under the same plan
        let mut rng = Xorshift128Plus::seed_from(5);
        let mut net = crate::models::cnn8(16, &mut rng);
        let d = crate::data::Dataset::synth(&crate::data::SynthConfig {
            train: 64,
            test: 16,
            size: 16,
            ..Default::default()
        });
        for _ in 0..3 {
            let (x, _) = d.gather_train(&(0..32).collect::<Vec<_>>());
            net.forward::<Xorshift128Plus>(&x, true, None);
        }
        let backend = SimBackend::new(PsbNetwork::prepare(&net, PsbOptions::default()));
        let (x, _) = d.gather_test(&(0..2).collect::<Vec<_>>());
        let out = adaptive_forward(&backend, &x, 4, 12, 17);
        // rebuild the same spatial plan from stage-1 features and run it
        // one-shot with the same seed
        let plan = crate::precision::SpatialAttention {
            n_low: 4,
            n_high: 12,
            threshold: Threshold::Mean,
        }
        .plan(&PlanContext::for_network(backend.network(), 2).with_feat(&out.stage1_feat))
        .unwrap();
        let mut direct = backend.open(&plan).unwrap();
        direct.begin(&x, 17).unwrap();
        assert_eq!(out.logits.data, direct.logits().data);
    }
}
