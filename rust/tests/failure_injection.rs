//! Failure injection: the system must fail loudly and precisely, not
//! corrupt state — bad artifact dirs, malformed metadata, truncated
//! bundles, shape mismatches.

use psb::coordinator::Engine;
use psb::runtime::{ArtifactMeta, FloatBundle, PsbBundle, Runtime};

#[test]
fn runtime_rejects_missing_artifact_dir() {
    let err = match Runtime::new("/nonexistent/psb-artifacts") {
        Ok(_) => panic!("must fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("meta.txt"), "should name the missing file: {msg}");
    assert!(msg.contains("make artifacts"), "should tell the user the fix: {msg}");
}

#[test]
fn engine_spawn_propagates_startup_error() {
    let psb = PsbBundle { layers: vec![] };
    let float = FloatBundle { layers: vec![] };
    let err = match Engine::spawn("/nonexistent".into(), psb, float, vec![]) {
        Ok(_) => panic!("must fail"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("meta.txt"));
}

#[test]
fn meta_parse_rejects_garbage() {
    for (text, what) in [
        ("", "empty"),
        ("image 32\nnum_classes 10\n", "incomplete"),
        ("image 32\nbogus record here\n", "unknown record"),
        ("image x\n", "bad number"),
        ("layer 0 27 sixteen 16\n", "bad layer field"),
    ] {
        assert!(ArtifactMeta::parse(text).is_err(), "{what} should fail");
    }
}

#[test]
fn meta_parse_accepts_minimal_valid() {
    let text = "\
image 32
num_classes 10
q16_scale 1024
layers 1
layer 0 27 16 16
sample_sizes 8
batches 1
module psb_n8_b1 psb 1 8
module float_b1 float 1 -
";
    let meta = ArtifactMeta::parse(text).unwrap();
    assert_eq!(meta.image, 32);
    assert_eq!(meta.modules["float_b1"].n, None);
    assert_eq!(meta.modules["psb_n8_b1"].n, Some(8));
}

#[test]
fn bundle_load_rejects_truncation_and_garbage() {
    let dir = std::env::temp_dir().join("psb-failure-tests");
    std::fs::create_dir_all(&dir).unwrap();

    let p1 = dir.join("empty.txt");
    std::fs::write(&p1, "").unwrap();
    assert!(FloatBundle::load(&p1).is_err());

    let p2 = dir.join("truncated.txt");
    std::fs::write(&p2, "float_bundle 1\nlayer 2 2\nw 1 2 3 4\n").unwrap();
    assert!(FloatBundle::load(&p2).is_err(), "missing bias line");

    let p3 = dir.join("badlen.txt");
    std::fs::write(&p3, "float_bundle 1\nlayer 2 2\nw 1 2 3\nbias 0 0\n").unwrap();
    assert!(FloatBundle::load(&p3).is_err(), "weight length mismatch");
}

#[test]
fn bundle_roundtrip_exact() {
    use psb::rng::{Rng, Xorshift128Plus};
    let mut rng = Xorshift128Plus::seed_from(4);
    let layers = vec![psb::runtime::bundle::FloatLayer {
        w: (0..12).map(|_| rng.uniform() - 0.5).collect(),
        bias: (0..4).map(|_| rng.uniform()).collect(),
        shape: [3, 4],
    }];
    let b = FloatBundle { layers };
    let dir = std::env::temp_dir().join("psb-failure-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("roundtrip.txt");
    b.save(&p).unwrap();
    let back = FloatBundle::load(&p).unwrap();
    assert_eq!(back.layers[0].shape, [3, 4]);
    for (a, c) in b.layers[0].w.iter().zip(&back.layers[0].w) {
        assert!((a - c).abs() < 1e-6);
    }
}

#[test]
fn bundle_from_wrong_network_shape_fails() {
    use psb::rng::Xorshift128Plus;
    let mut rng = Xorshift128Plus::seed_from(9);
    let net = psb::models::cnn8(32, &mut rng); // 8 convs — not the serving CNN
    let serving = [[27usize, 16], [144, 32], [288, 32], [32, 10]];
    assert!(FloatBundle::from_network(&net, &serving).is_err());
}
