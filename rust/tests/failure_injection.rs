//! Failure injection: the system must fail loudly and precisely, not
//! corrupt state — bad artifact dirs, malformed metadata, truncated
//! bundles, shape mismatches, dead engines that still name their root
//! cause.

use psb::backend::{pjrt_factory, sim_factory};
use psb::coordinator::Engine;
use psb::precision::PrecisionPlan;
use psb::rng::{RngKind, Xorshift128Plus};
use psb::runtime::{ArtifactMeta, FloatBundle, PsbBundle, Runtime};
use psb::sim::network::{Network, Op};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};

#[test]
fn runtime_rejects_missing_artifact_dir() {
    let err = match Runtime::new("/nonexistent/psb-artifacts") {
        Ok(_) => panic!("must fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("meta.txt"), "should name the missing file: {msg}");
    assert!(msg.contains("make artifacts"), "should tell the user the fix: {msg}");
}

#[test]
fn engine_spawn_propagates_startup_error() {
    let psb = PsbBundle { layers: vec![] };
    let err = match Engine::spawn(pjrt_factory("/nonexistent".into(), psb, 8, vec![])) {
        Ok(_) => panic!("must fail"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("meta.txt"));
}

fn tiny_psbnet() -> PsbNetwork {
    let mut net = Network::new((8, 8, 3), "failure-test");
    let c1 = net.add(Op::Conv { k: 3, stride: 2, cin: 3, cout: 4 }, vec![0], "c1");
    let r1 = net.add(Op::ReLU, vec![c1], "r1");
    net.feat_node = Some(r1);
    let g = net.add(Op::GlobalAvgPool, vec![r1], "gap");
    net.add(Op::Dense { cin: 4, cout: 2 }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(3);
    net.init(&mut rng);
    PsbNetwork::prepare(&net, PsbOptions::default())
}

#[test]
fn engine_keeps_root_cause_of_backend_failures() {
    let engine = Engine::spawn(sim_factory(tiny_psbnet(), RngKind::Xorshift)).unwrap();
    // malformed job: input length does not match the geometry
    let err = engine.run_once(PrecisionPlan::uniform(4), vec![0.0; 7], 1, 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("input size"), "job error should name the cause: {msg}");
    // the failure is retained for post-mortem queries (and would be
    // appended to a submit-after-death error)
    let last = engine.last_error().expect("failure must be recorded");
    assert!(last.contains("input size"), "recorded cause: {last}");
    // the engine survives a failed job: a well-formed one still runs
    let ok = engine
        .run_once(PrecisionPlan::uniform(4), vec![0.1; 8 * 8 * 3], 1, 1)
        .expect("engine must keep serving after a bad job");
    assert_eq!(ok.exec.logits.len(), 2);
}

#[test]
fn chaos_faults_surface_as_named_errors_and_fill_the_ring() {
    use psb::backend::{chaos_factory, ChaosConfig};
    // heavy transient mix, no poison/geometry: every fault is a plain
    // named error on the job that drew it
    let cfg = ChaosConfig {
        seed: 41,
        transient_permille: 400,
        permanent_permille: 50,
        slow_permille: 0,
        poison_permille: 0,
        geometry_permille: 0,
        ..ChaosConfig::seeded(41)
    };
    let (factory, stats) = chaos_factory(sim_factory(tiny_psbnet(), RngKind::Xorshift), cfg);
    let engine = Engine::spawn(factory).unwrap();
    let x: Vec<f32> = (0..8 * 8 * 3).map(|i| i as f32 * 0.01).collect();
    let mut failed = 0u32;
    let mut served = 0u32;
    for seed in 0..32u64 {
        match engine.run_once(PrecisionPlan::uniform(4), x.clone(), 1, seed) {
            Ok(out) => {
                assert_eq!(out.exec.logits.len(), 2);
                served += 1;
            }
            Err(err) => {
                let msg = format!("{err:#}");
                assert!(
                    msg.contains("chaos: injected fault"),
                    "chaos failures must be named, numbered faults: {msg}"
                );
                assert!(
                    msg.contains("(transient)") || msg.contains("(permanent)"),
                    "chaos failures must carry a retryability marker: {msg}"
                );
                failed += 1;
            }
        }
    }
    assert!(failed > 0, "a 45% fault mix over 32 ops must fault at least once");
    assert!(served > 0, "the engine must keep serving between faults");
    assert!(stats.total_faults() >= failed as u64);
    // the ring retained multiple distinct root causes, bounded at 16
    let recent = engine.recent_errors();
    assert!(!recent.is_empty() && recent.len() <= 16, "bounded ring: {}", recent.len());
    assert!(recent.iter().all(|e| e.contains("chaos: injected fault")));
    // the newest retained error is the ring's `last()` answer
    assert_eq!(engine.last_error().as_deref(), recent.last().map(String::as_str));
}

#[test]
fn meta_parse_rejects_garbage() {
    for (text, what) in [
        ("", "empty"),
        ("image 32\nnum_classes 10\n", "incomplete"),
        ("image 32\nbogus record here\n", "unknown record"),
        ("image x\n", "bad number"),
        ("layer 0 27 sixteen 16\n", "bad layer field"),
    ] {
        assert!(ArtifactMeta::parse(text).is_err(), "{what} should fail");
    }
}

#[test]
fn meta_parse_accepts_minimal_valid() {
    let text = "\
image 32
num_classes 10
q16_scale 1024
layers 1
layer 0 27 16 16
sample_sizes 8
batches 1
module psb_n8_b1 psb 1 8
module float_b1 float 1 -
";
    let meta = ArtifactMeta::parse(text).unwrap();
    assert_eq!(meta.image, 32);
    assert_eq!(meta.modules["float_b1"].n, None);
    assert_eq!(meta.modules["psb_n8_b1"].n, Some(8));
}

#[test]
fn bundle_load_rejects_truncation_and_garbage() {
    let dir = std::env::temp_dir().join("psb-failure-tests");
    std::fs::create_dir_all(&dir).unwrap();

    let p1 = dir.join("empty.txt");
    std::fs::write(&p1, "").unwrap();
    assert!(FloatBundle::load(&p1).is_err());

    let p2 = dir.join("truncated.txt");
    std::fs::write(&p2, "float_bundle 1\nlayer 2 2\nw 1 2 3 4\n").unwrap();
    assert!(FloatBundle::load(&p2).is_err(), "missing bias line");

    let p3 = dir.join("badlen.txt");
    std::fs::write(&p3, "float_bundle 1\nlayer 2 2\nw 1 2 3\nbias 0 0\n").unwrap();
    assert!(FloatBundle::load(&p3).is_err(), "weight length mismatch");
}

#[test]
fn bundle_roundtrip_exact() {
    use psb::rng::{Rng, Xorshift128Plus};
    let mut rng = Xorshift128Plus::seed_from(4);
    let layers = vec![psb::runtime::bundle::FloatLayer {
        w: (0..12).map(|_| rng.uniform() - 0.5).collect(),
        bias: (0..4).map(|_| rng.uniform()).collect(),
        shape: [3, 4],
    }];
    let b = FloatBundle { layers };
    let dir = std::env::temp_dir().join("psb-failure-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("roundtrip.txt");
    b.save(&p).unwrap();
    let back = FloatBundle::load(&p).unwrap();
    assert_eq!(back.layers[0].shape, [3, 4]);
    for (a, c) in b.layers[0].w.iter().zip(&back.layers[0].w) {
        assert!((a - c).abs() < 1e-6);
    }
}

#[test]
fn bundle_from_wrong_network_shape_fails() {
    use psb::rng::Xorshift128Plus;
    let mut rng = Xorshift128Plus::seed_from(9);
    let net = psb::models::cnn8(32, &mut rng); // 8 convs — not the serving CNN
    let serving = [[27usize, 16], [144, 32], [288, 32], [32, 10]];
    assert!(FloatBundle::from_network(&net, &serving).is_err());
}
