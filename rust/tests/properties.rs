//! Hand-rolled property tests (the offline build has no proptest crate;
//! cases are generated from the in-tree RNG with fixed seeds and shrunk
//! manually by printing the failing case).
//!
//! Invariants covered:
//! * PSB encoding bijectivity + range invariants across the float range;
//! * variance bound Var(w̄_n) ≤ w²/(8n) (Eq. 10) across (w, n);
//! * Q16 quantization idempotence and monotonicity;
//! * binomial sampler bounds + moments across (n, p);
//! * BN folding preserves eval-mode outputs on random DAGs;
//! * bit-exact integer capacitor path is unbiased vs the float weights;
//! * probability discretization error bound |Δw| ≤ 2^e / 2^bits.

use psb::num::{discretize_prob, quantize_f32, PsbWeight, Q16};
use psb::rng::{binomial::binomial_inversion, Rng, Xorshift128Plus};
use psb::sim::fold::fold_batchnorms;
use psb::sim::network::{Network, Op};
use psb::sim::tensor::Tensor;

const CASES: usize = 300;

fn random_weight(rng: &mut impl Rng) -> f32 {
    // log-uniform magnitude over ~12 octaves, random sign, some zeros
    if rng.below(50) == 0 {
        return 0.0;
    }
    let mag = (-6.0 + 12.0 * rng.uniform()) as f32;
    let sign = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
    sign * mag.exp2() * (1.0 + rng.uniform())
}

#[test]
fn prop_encoding_bijective_and_ranged() {
    let mut rng = Xorshift128Plus::seed_from(101);
    for case in 0..CASES {
        let w = random_weight(&mut rng);
        let e = PsbWeight::encode(w);
        let back = e.decode();
        assert!(
            (back - w).abs() <= 2e-6 * w.abs().max(1e-9),
            "case {case}: w={w} back={back}"
        );
        if w != 0.0 {
            assert!((0.0..1.0).contains(&e.prob), "case {case}: p={}", e.prob);
            let lo = (e.exp as f32).exp2();
            assert!(lo <= w.abs() * (1.0 + 1e-6), "case {case}: w={w} e={}", e.exp);
            assert!(w.abs() < 2.0 * lo * (1.0 + 1e-6), "case {case}: w={w} e={}", e.exp);
        } else {
            assert_eq!(e.sign, 0);
        }
    }
}

#[test]
fn prop_variance_bound_eq10() {
    let mut rng = Xorshift128Plus::seed_from(202);
    for case in 0..40 {
        let w = random_weight(&mut rng);
        if w == 0.0 {
            continue;
        }
        let n = 1 << rng.below(7); // 1..64
        let e = PsbWeight::encode(w);
        let trials = 4000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..trials {
            let v = e.sample_n(n as u32, &mut rng) as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / trials as f64;
        let var = (s2 / trials as f64 - mean * mean).max(0.0);
        let bound = (w as f64).powi(2) / (8.0 * n as f64);
        assert!(
            var <= bound * 1.35 + 1e-12,
            "case {case}: w={w} n={n} var={var} bound={bound}"
        );
    }
}

#[test]
fn prop_q16_idempotent_monotone_bounded() {
    let mut rng = Xorshift128Plus::seed_from(303);
    let mut prev_in = f32::NEG_INFINITY;
    let mut prev_out = f32::NEG_INFINITY;
    let mut vals: Vec<f32> = (0..CASES).map(|_| (rng.uniform() - 0.5) * 80.0).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for v in vals {
        let q = quantize_f32(v);
        assert_eq!(q, quantize_f32(q), "idempotence at {v}");
        assert!((-32.0..=32.0).contains(&q), "range at {v}");
        assert!(q >= prev_out || v == prev_in, "monotonicity at {v}");
        assert_eq!(q, Q16::from_f32(v).to_f32(), "struct/f32 agreement at {v}");
        prev_in = v;
        prev_out = q;
    }
}

#[test]
fn prop_binomial_bounds_and_mean() {
    let mut rng = Xorshift128Plus::seed_from(404);
    for case in 0..60 {
        let n = 1 + rng.below(256) as u32;
        let p = rng.uniform();
        let trials = 2000;
        let mut sum = 0u64;
        for _ in 0..trials {
            let k = binomial_inversion(&mut rng, n, p);
            assert!(k <= n, "case {case}: k={k} > n={n}");
            sum += k as u64;
        }
        let mean = sum as f64 / trials as f64;
        let expect = n as f64 * p as f64;
        let sd = (n as f64 * p as f64 * (1.0 - p as f64)).sqrt();
        assert!(
            (mean - expect).abs() < 5.0 * sd / (trials as f64).sqrt() + 0.05,
            "case {case}: n={n} p={p} mean={mean} expect={expect}"
        );
    }
}

/// Build a random small DAG with conv/bn/relu/add/depthwise structure.
fn random_net(rng: &mut impl Rng) -> Network {
    let mut net = Network::new((8, 8, 3), "prop");
    let mut frontier = 0usize; // current trunk node
    let mut channels = 3usize;
    let blocks = 1 + rng.below(3) as usize;
    for b in 0..blocks {
        let cout = [4usize, 8][rng.below(2) as usize];
        let stride = 1 + rng.below(2) as usize;
        let c = net.add(
            Op::Conv { k: 3, stride, cin: channels, cout },
            vec![frontier],
            &format!("c{b}"),
        );
        let with_bn = rng.bernoulli(0.8);
        let mut tip = c;
        if with_bn {
            tip = net.add(Op::BatchNorm, vec![tip], &format!("bn{b}"));
        }
        tip = net.add(Op::ReLU, vec![tip], &format!("r{b}"));
        // optional residual add when shapes allow
        if stride == 1 && cout == channels && rng.bernoulli(0.5) {
            tip = net.add(Op::Add, vec![tip, frontier], &format!("a{b}"));
        }
        frontier = tip;
        channels = cout;
    }
    let g = net.add(Op::GlobalAvgPool, vec![frontier], "gap");
    net.add(Op::Dense { cin: channels, cout: 4 }, vec![g], "fc");
    net.init(rng);
    net
}

#[test]
fn prop_bn_folding_preserves_eval_output() {
    let mut rng = Xorshift128Plus::seed_from(505);
    for case in 0..25 {
        let mut net = random_net(&mut rng);
        // materialize BN stats with a few training-mode forwards
        for s in 0..4 {
            let x = Tensor::from_vec(
                (0..2 * 8 * 8 * 3).map(|_| rng.uniform()).collect(),
                &[2, 8, 8, 3],
            );
            let _ = s;
            net.forward::<Xorshift128Plus>(&x, true, None);
        }
        let x = Tensor::from_vec(
            (0..2 * 8 * 8 * 3).map(|_| rng.uniform()).collect(),
            &[2, 8, 8, 3],
        );
        let before = net.forward::<Xorshift128Plus>(&x, false, None).logits().clone();
        fold_batchnorms(&mut net);
        let after = net.forward::<Xorshift128Plus>(&x, false, None).logits().clone();
        for (a, b) in before.data.iter().zip(&after.data) {
            assert!((a - b).abs() < 2e-3, "case {case}: {a} vs {b}");
        }
    }
}

#[test]
fn prop_exact_integer_capacitor_unbiased() {
    use psb::costs::CostCounter;
    use psb::num::PsbPlanes;
    use psb::sim::capacitor::capacitor_matmul_exact;
    let mut rng = Xorshift128Plus::seed_from(606);
    for case in 0..10 {
        let k = 1 + rng.below(6) as usize;
        let n_out = 1 + rng.below(4) as usize;
        let w: Vec<f32> = (0..k * n_out).map(|_| random_weight(&mut rng).clamp(-4.0, 4.0)).collect();
        let planes = PsbPlanes::encode(&w, &[k, n_out]);
        let x: Vec<f32> = (0..k).map(|_| quantize_f32(rng.uniform() * 2.0 - 1.0)).collect();
        let xq: Vec<Q16> = x.iter().map(|&v| Q16::from_f32(v)).collect();
        let want = psb::sim::tensor::matmul(&x, &w, 1, k, n_out);
        let trials = 600u64;
        let mut mean = vec![0.0f64; n_out];
        let mut costs = CostCounter::default();
        for t in 0..trials {
            let y = capacitor_matmul_exact(&xq, &planes, None, 1, 16, t * 7 + case, &mut costs);
            for (m, v) in mean.iter_mut().zip(&y) {
                *m += v.to_f32() as f64;
            }
        }
        for (j, (m, w)) in mean.iter().zip(&want).enumerate() {
            let m = m / trials as f64;
            // integer grid + sampling noise tolerance
            let tol = 0.08 * w.abs().max(0.5) as f64;
            assert!((m - *w as f64).abs() < tol, "case {case} out {j}: mean {m} want {w}");
        }
    }
}

#[test]
fn prop_discretization_error_bound() {
    let mut rng = Xorshift128Plus::seed_from(707);
    for case in 0..CASES {
        let w = random_weight(&mut rng);
        if w == 0.0 {
            continue;
        }
        let bits = 1 + rng.below(6) as u32;
        let e = PsbWeight::encode(w);
        let q = PsbWeight { prob: discretize_prob(e.prob, bits), ..e };
        let err = (q.decode() - w).abs();
        let bound = (e.exp as f32).exp2() / (1u32 << bits) as f32;
        assert!(err <= bound + 1e-6, "case {case}: w={w} bits={bits} err={err} bound={bound}");
    }
}
