//! Backend parity: the unified execution API's core contracts,
//! hand-rolled property style (fixed-seed case generation, as in
//! `properties.rs` — the offline build has no proptest crate).
//!
//! * `SimBackend` (over an `exact_integer` network) and `IntKernel`
//!   produce **identical logits** for the same `(seed, plan)` — the
//!   integer shift-add kernel is byte-for-byte the sim's Eq. 9 datapath;
//! * `refine` through session-cached accumulators is **bit-identical**
//!   to a one-shot pass at the target plan, on every backend;
//! * per-layer escalations reuse the session cache (untouched layers
//!   execute nothing; the integer kernel delta-updates clean layers);
//! * stage charges partition the one-shot charge exactly (Eq. 8's cost
//!   additivity);
//! * narrowing a session to a row subset preserves bit-identity
//!   (filter draws are shared across the batch);
//! * `IntKernel` rejects what the integer datapath cannot express.

use psb::backend::intkernel::{Contraction, DirectConv, IntKernelConfig};
use psb::backend::{Backend, InferenceSession, IntKernel, KernelPath, SimBackend};
use psb::precision::PrecisionPlan;
use psb::rng::{Rng, Xorshift128Plus};
use psb::sim::network::{Network, Op};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::tensor::Tensor;

/// Foldable conv net (no depthwise, no residual BN) — the graph shape
/// both backends can execute.
fn make_net() -> Network {
    let mut net = Network::new((8, 8, 3), "parity-test");
    let c1 = net.add(Op::Conv { k: 3, stride: 2, cin: 3, cout: 8 }, vec![0], "c1");
    let b1 = net.add(Op::BatchNorm, vec![c1], "bn1");
    let r1 = net.add(Op::ReLU, vec![b1], "r1");
    let c2 = net.add(Op::Conv { k: 3, stride: 1, cin: 8, cout: 8 }, vec![r1], "c2");
    let b2 = net.add(Op::BatchNorm, vec![c2], "bn2");
    let a = net.add(Op::Add, vec![b2, r1], "add");
    let r2 = net.add(Op::ReLU, vec![a], "r2");
    net.feat_node = Some(r2);
    let g = net.add(Op::GlobalAvgPool, vec![r2], "gap");
    net.add(Op::Dense { cin: 8, cout: 4 }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(21);
    net.init(&mut rng);
    net
}

fn prepared(options: PsbOptions) -> PsbNetwork {
    let mut net = make_net();
    for s in 0..8 {
        let x = batch(s, 4);
        net.forward::<Xorshift128Plus>(&x, true, None);
    }
    PsbNetwork::prepare(&net, options)
}

fn batch(seed: u64, b: usize) -> Tensor {
    let mut rng = Xorshift128Plus::seed_from(seed);
    Tensor::from_vec((0..b * 8 * 8 * 3).map(|_| rng.uniform()).collect(), &[b, 8, 8, 3])
}

/// Both backends over the *same* prepared planes; the sim runs the
/// bit-exact integer datapath so the comparison is exact, not
/// statistical.
fn backend_pair() -> (SimBackend, IntKernel) {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let int = IntKernel::new(net).expect("parity net is integer-expressible");
    (sim, int)
}

fn one_shot(backend: &dyn Backend, x: &Tensor, plan: &PrecisionPlan, seed: u64) -> Vec<f32> {
    let mut sess = backend.open(plan).unwrap();
    sess.begin(x, seed).unwrap();
    sess.logits().data.clone()
}

#[test]
fn prop_int_kernel_matches_exact_sim() {
    let (sim, int) = backend_pair();
    let x = batch(42, 2);
    let plans = [
        PrecisionPlan::uniform(4),
        PrecisionPlan::uniform(16),
        PrecisionPlan::per_layer(&[4, 8, 16]).unwrap(),
    ];
    for seed in 0..5u64 {
        for plan in &plans {
            let a = one_shot(&sim, &x, plan, seed);
            let b = one_shot(&int, &x, plan, seed);
            assert_eq!(a, b, "sim(exact) vs int kernel diverged: seed={seed} plan={plan:?}");
        }
    }
}

#[test]
fn prop_refine_from_cache_is_bit_identical_to_one_shot() {
    let (sim, int) = backend_pair();
    let x = batch(7, 2);
    let target = PrecisionPlan::uniform(16);
    for seed in 0..5u64 {
        let mut results = Vec::new();
        for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
            let direct = one_shot(backend, &x, &target, seed);
            let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&x, seed).unwrap();
            sess.refine(&PrecisionPlan::uniform(8)).unwrap();
            sess.refine(&target).unwrap();
            assert_eq!(
                sess.logits().data, direct,
                "[{}] 4→8→16 must equal one-shot 16 (seed {seed})",
                backend.name()
            );
            results.push(direct);
        }
        assert_eq!(results[0], results[1], "backends diverged after refinement chain");
    }
}

#[test]
fn per_layer_escalation_reuses_the_session_cache() {
    let (sim, int) = backend_pair();
    let x = batch(11, 2);
    let lo = PrecisionPlan::per_layer(&[4, 4, 4]).unwrap();
    let hi = PrecisionPlan::per_layer(&[4, 16, 16]).unwrap();
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let direct = one_shot(backend, &x, &hi, 3);
        let mut sess = backend.open(&lo).unwrap();
        let s1 = sess.begin(&x, 3).unwrap();
        let s2 = sess.refine(&hi).unwrap();
        assert_eq!(sess.logits().data, direct, "[{}] cached escalation", backend.name());
        // layer 0 kept n=4 over the (clean) input: served from the cache
        assert!(s2.nodes_reused >= 1, "[{}] expected cache reuse: {s2:?}", backend.name());
        assert!(
            s2.executed_adds < s1.executed_adds,
            "[{}] escalation must execute less than the opening pass: {} vs {}",
            backend.name(),
            s2.executed_adds,
            s1.executed_adds
        );
    }
    // the integer kernel additionally delta-updates the first touched
    // clean-input layer instead of rebuilding it
    let mut sess = int.open(&PrecisionPlan::uniform(4)).unwrap();
    sess.begin(&x, 3).unwrap();
    let step = sess.refine(&PrecisionPlan::uniform(16)).unwrap();
    assert!(step.delta_updated >= 1, "O(Δ) delta path must engage: {step:?}");
}

#[test]
fn stage_charges_partition_the_one_shot_charge() {
    let (sim, int) = backend_pair();
    let x = batch(5, 2);
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut fresh = backend.open(&PrecisionPlan::uniform(16)).unwrap();
        let full = fresh.begin(&x, 9).unwrap();
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        let a = sess.begin(&x, 9).unwrap();
        let b = sess.refine(&PrecisionPlan::uniform(16)).unwrap();
        assert_eq!(
            a.costs.gated_adds + b.costs.gated_adds,
            full.costs.gated_adds,
            "[{}] stage charges must partition the direct pass",
            backend.name()
        );
        assert!(b.costs.gated_adds < full.costs.gated_adds);
        // the session's cumulative report agrees
        assert_eq!(sess.cost_report().total.gated_adds, full.costs.gated_adds);
    }
}

#[test]
fn narrowed_sessions_refine_bit_identically() {
    let (sim, int) = backend_pair();
    let x = batch(13, 4);
    let rows = [1usize, 3];
    let xr = gather_rows(&x, &rows);
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        sess.begin(&x, 6).unwrap();
        sess.narrow(&rows).unwrap();
        sess.refine(&PrecisionPlan::uniform(16)).unwrap();
        // reference: the same rows, never having seen the other rows —
        // filter draws are row-independent, so the logits agree exactly
        let mut reference = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        reference.begin(&xr, 6).unwrap();
        reference.refine(&PrecisionPlan::uniform(16)).unwrap();
        assert_eq!(
            sess.logits().data,
            reference.logits().data,
            "[{}] narrow must not perturb refinement",
            backend.name()
        );
        assert_eq!(sess.logits().shape, vec![2, 4]);
    }
}

#[test]
fn failed_refine_leaves_the_session_consistent() {
    // A non-monotonic target rejected at a *later* layer still advances
    // earlier layers' counts before erroring.  The session must not
    // serve stale cached activations afterwards: a subsequent valid
    // refine has to be bit-identical to a one-shot pass at the merged
    // counts (here: every layer ends at 16 under the same streams).
    let (sim, int) = backend_pair();
    let x = batch(23, 2);
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut sess = backend.open(&PrecisionPlan::uniform(8)).unwrap();
        sess.begin(&x, 5).unwrap();
        // layer 0 escalates to 16, layer 1 asks for 2 < 8 -> rejected
        let bad = PrecisionPlan::per_layer(&[16, 2]).unwrap();
        assert!(sess.refine(&bad).is_err(), "[{}] downgrade must error", backend.name());
        sess.refine(&PrecisionPlan::uniform(16)).unwrap();
        let direct = one_shot(backend, &x, &PrecisionPlan::uniform(16), 5);
        assert_eq!(
            sess.logits().data, direct,
            "[{}] retry after a failed refine must not serve stale caches",
            backend.name()
        );
    }
}

#[test]
fn sim_float_sessions_match_direct_progressive_passes() {
    // the default (float-carried) sim path: session caching must be a
    // pure wall-time optimization
    let net = prepared(PsbOptions::default());
    let backend = SimBackend::new(net.clone());
    let x = batch(17, 2);
    let mut sess = backend.open(&PrecisionPlan::uniform(6)).unwrap();
    sess.begin(&x, 4).unwrap();
    sess.refine(&PrecisionPlan::uniform(16)).unwrap();
    let mut st = net.begin(backend.rng(), 4);
    net.refine(&x, &mut st, &PrecisionPlan::uniform(6)).unwrap();
    let direct = net.refine(&x, &mut st, &PrecisionPlan::uniform(16)).unwrap();
    assert_eq!(sess.logits().data, direct.logits.data);
}

#[test]
fn int_kernel_rejects_what_it_cannot_express() {
    // unfoldable (residual) stochastic BNs need a stochastic multiply
    let mut resid = Network::new((8, 8, 3), "resid-bn");
    let r1 = net_stem(&mut resid);
    let c2 = resid.add(Op::Conv { k: 3, stride: 1, cin: 8, cout: 8 }, vec![r1], "c2");
    let a = resid.add(Op::Add, vec![c2, r1], "add");
    let b2 = resid.add(Op::BatchNorm, vec![a], "bn2");
    let r2 = resid.add(Op::ReLU, vec![b2], "r2");
    let g = resid.add(Op::GlobalAvgPool, vec![r2], "gap");
    resid.add(Op::Dense { cin: 8, cout: 4 }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(2);
    resid.init(&mut rng);
    for s in 0..4 {
        let x = batch(s, 2);
        resid.forward::<Xorshift128Plus>(&x, true, None);
    }
    let psb = PsbNetwork::prepare(&resid, PsbOptions::default());
    assert!(IntKernel::new(psb).is_err(), "unfoldable stochastic BN must be rejected");

    // depthwise capacitors are now expressible (packed depthwise kernel)
    let psb = PsbNetwork::prepare(&make_depthwise_net(), PsbOptions::default());
    assert!(IntKernel::new(psb).is_ok(), "depthwise is supported since the packed kernel");

    // the deterministic §4.4 variant
    let det = prepared(PsbOptions { deterministic: true, prob_bits: Some(4), ..Default::default() });
    assert!(IntKernel::new(det).is_err(), "deterministic variant must be rejected");

    // spatial plans run natively since the row-masked contraction; only
    // non-power-of-two levels (either track) are refused
    let (_, int) = backend_pair();
    assert!(
        int.open(&PrecisionPlan::spatial(vec![true; 64], 4, 8)).is_ok(),
        "masked plans execute on the row-masked IntKernel"
    );
    assert!(
        int.open(&PrecisionPlan::spatial(vec![true; 64], 4, 12)).is_err(),
        "12 on the attended track is not a power of two"
    );
    assert!(int.open(&PrecisionPlan::uniform(6)).is_err());
    let mut sess = int.open(&PrecisionPlan::uniform(4)).unwrap();
    let x = batch(1, 1);
    sess.begin(&x, 1).unwrap();
    assert!(sess.refine(&PrecisionPlan::uniform(12)).is_err(), "12 is not a power of two");
}

fn net_stem(net: &mut Network) -> usize {
    let c1 = net.add(Op::Conv { k: 3, stride: 1, cin: 3, cout: 8 }, vec![0], "c1");
    net.add(Op::ReLU, vec![c1], "r1")
}

/// Conv stem + depthwise + dense head — the MobileNet-ish graph shape
/// the packed depthwise kernel opens to the integer backend.
fn make_depthwise_net() -> Network {
    let mut net = Network::new((8, 8, 3), "dw-parity");
    let r1 = net_stem(&mut net);
    let d1 = net.add(Op::Depthwise { k: 3, stride: 2, c: 8 }, vec![r1], "dw1");
    let r2 = net.add(Op::ReLU, vec![d1], "r2");
    net.feat_node = Some(r2);
    let g = net.add(Op::GlobalAvgPool, vec![r2], "gap");
    net.add(Op::Dense { cin: 8, cout: 4 }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(7);
    net.init(&mut rng);
    net
}

/// The packed, parallel contraction must be **bit-identical** to the
/// scalar i32 reference — one-shot, across refinement chains, after
/// `narrow`, for any thread count, and on reduction lengths below,
/// above and not a multiple of the 64-bit packing width (dense kdim 8,
/// stem kdim 27, conv kdim 72 here).
#[test]
fn prop_packed_contraction_matches_scalar_bit_identically() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let scalar = IntKernel::new(net.clone())
        .unwrap()
        .with_contraction(Contraction::Scalar);
    let packed: Vec<IntKernel> = [0usize, 1, 3]
        .iter()
        .map(|&t| IntKernel::new(net.clone()).unwrap().with_threads(t))
        .collect();
    let x = batch(31, 4);
    let plans = [
        PrecisionPlan::uniform(4),
        PrecisionPlan::uniform(16),
        PrecisionPlan::per_layer(&[4, 8, 16]).unwrap(),
    ];
    for seed in 0..3u64 {
        for plan in &plans {
            let want = one_shot(&scalar, &x, plan, seed);
            for (pi, p) in packed.iter().enumerate() {
                assert_eq!(
                    one_shot(p, &x, plan, seed),
                    want,
                    "packed[{pi}] diverged from scalar: seed={seed} plan={plan:?}"
                );
            }
        }
        // refine chain + narrow, against the scalar session doing the same
        let mut sref = scalar.open(&PrecisionPlan::uniform(4)).unwrap();
        sref.begin(&x, seed).unwrap();
        sref.narrow(&[0, 2]).unwrap();
        sref.refine(&PrecisionPlan::uniform(8)).unwrap();
        sref.refine(&PrecisionPlan::uniform(32)).unwrap();
        for (pi, p) in packed.iter().enumerate() {
            let mut sess = p.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&x, seed).unwrap();
            sess.narrow(&[0, 2]).unwrap();
            sess.refine(&PrecisionPlan::uniform(8)).unwrap();
            let step = sess.refine(&PrecisionPlan::uniform(32)).unwrap();
            assert_eq!(
                sess.logits().data,
                sref.logits().data,
                "packed[{pi}] narrowed refine chain diverged (seed {seed})"
            );
            assert!(step.delta_updated >= 1, "packed delta path must engage: {step:?}");
        }
    }
}

/// The multi-word *blocked* contraction is bit-identical to the packed
/// word-at-a-time walk, the scalar reference and the exact sim — one-
/// shot across plans, and through narrowed refine chains, at thread
/// counts 0 (auto), 1 and 3.  The parity net spans kdim 8 (dense,
/// sub-word), 27 (stem) and 72 (conv, multi-word), so the 4-word inner
/// block runs its tail handling on every pass.
#[test]
fn prop_blocked_contraction_matches_all_datapaths_bit_identically() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let scalar = IntKernel::new(net.clone())
        .unwrap()
        .with_contraction(Contraction::Scalar);
    let packed = IntKernel::new(net.clone()).unwrap();
    let blocked: Vec<IntKernel> = [0usize, 1, 3]
        .iter()
        .map(|&t| {
            IntKernel::new(net.clone())
                .unwrap()
                .with_contraction(Contraction::Blocked)
                .with_threads(t)
        })
        .collect();
    let x = batch(53, 4);
    let plans = [
        PrecisionPlan::uniform(4),
        PrecisionPlan::uniform(16),
        PrecisionPlan::per_layer(&[4, 8, 16]).unwrap(),
    ];
    for seed in 0..3u64 {
        for plan in &plans {
            let want = one_shot(&sim, &x, plan, seed);
            assert_eq!(
                one_shot(&scalar, &x, plan, seed),
                want,
                "scalar diverged from exact sim: seed={seed} plan={plan:?}"
            );
            assert_eq!(
                one_shot(&packed, &x, plan, seed),
                want,
                "packed diverged from exact sim: seed={seed} plan={plan:?}"
            );
            for (bi, b) in blocked.iter().enumerate() {
                assert_eq!(
                    one_shot(b, &x, plan, seed),
                    want,
                    "blocked[{bi}] diverged from exact sim: seed={seed} plan={plan:?}"
                );
            }
        }
        // narrowed refine chain, against the scalar session doing the
        // same — the blocked masked-step and delta drivers both engage
        let mut sref = scalar.open(&PrecisionPlan::uniform(4)).unwrap();
        sref.begin(&x, seed).unwrap();
        sref.narrow(&[0, 2]).unwrap();
        sref.refine(&PrecisionPlan::uniform(8)).unwrap();
        sref.refine(&PrecisionPlan::uniform(32)).unwrap();
        for (bi, b) in blocked.iter().enumerate() {
            let mut sess = b.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&x, seed).unwrap();
            sess.narrow(&[0, 2]).unwrap();
            sess.refine(&PrecisionPlan::uniform(8)).unwrap();
            let step = sess.refine(&PrecisionPlan::uniform(32)).unwrap();
            assert_eq!(
                sess.logits().data,
                sref.logits().data,
                "blocked[{bi}] narrowed refine chain diverged (seed {seed})"
            );
            assert!(step.delta_updated >= 1, "blocked delta path must engage: {step:?}");
            assert_eq!(step.kernel_path, KernelPath::Blocked, "step must carry its datapath tag");
        }
    }
}

/// Masked (spatial) execution through the blocked driver: one-shot
/// spatial plans and attend→refine chains on `Contraction::Blocked` are
/// bit-identical to the exact sim at thread counts 0/1/3, with the same
/// per-row billing.
#[test]
fn prop_masked_blocked_matches_masked_exact_sim() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let blocked: Vec<IntKernel> = [0usize, 1, 3]
        .iter()
        .map(|&t| {
            IntKernel::new(net.clone())
                .unwrap()
                .with_contraction(Contraction::Blocked)
                .with_threads(t)
        })
        .collect();
    let x = batch(37, 4);
    let mask = top_rows_mask(4, 8, 8, 0.5);
    let plans = [
        PrecisionPlan::spatial(mask.clone(), 4, 16),
        PrecisionPlan::per_layer(&[4, 8, 16]).unwrap().with_mask(mask.clone()),
    ];
    for seed in 0..3u64 {
        for plan in &plans {
            let want = one_shot(&sim, &x, plan, seed);
            for (bi, b) in blocked.iter().enumerate() {
                assert_eq!(
                    one_shot(b, &x, plan, seed),
                    want,
                    "blocked[{bi}] masked vs exact sim: seed={seed}"
                );
            }
        }
        let s2 = PrecisionPlan::spatial(mask.clone(), 4, 8);
        let s3 = PrecisionPlan::spatial(mask.clone(), 8, 32);
        let chain = |backend: &dyn Backend| {
            let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&x, seed).unwrap();
            sess.refine(&s2).unwrap();
            sess.refine(&s3).unwrap();
            (sess.logits().data.clone(), sess.cost_report().total.gated_adds)
        };
        let (want, want_adds) = chain(&sim);
        for (bi, b) in blocked.iter().enumerate() {
            let (got, got_adds) = chain(b);
            assert_eq!(got, want, "blocked[{bi}] masked chain diverged (seed {seed})");
            assert_eq!(got_adds, want_adds, "blocked[{bi}] billing diverged (seed {seed})");
        }
    }
}

/// The im2col-free direct convolution walk (`DirectConv::Always`) is a
/// pure execution-order change: begins produce bit-identical logits,
/// *identical executed adds* and identical charges to the cached-
/// lowering path — and the caches a direct begin leaves behind carry
/// O(Δ) refines and frame rebases bit-identically, on both packed-
/// layout contraction modes.
#[test]
fn prop_direct_conv_begin_composes_with_refine_and_rebase() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let x0 = batch(91, 3);
    // image 0's top pixel rows drift, the rest of the batch is clean
    let mut x1 = x0.clone();
    for v in x1.data[..2 * 8 * 3].iter_mut() {
        *v += 0.25;
    }
    for mode in [Contraction::Packed, Contraction::Blocked] {
        let of = |dc: DirectConv| {
            IntKernel::new(net.clone())
                .unwrap()
                .with_contraction(mode)
                .with_config(IntKernelConfig { direct_conv: dc, ..Default::default() })
        };
        let always = of(DirectConv::Always);
        let never = of(DirectConv::Never);
        for seed in 0..3u64 {
            let mut sa = always.open(&PrecisionPlan::uniform(8)).unwrap();
            let ba = sa.begin(&x0, seed).unwrap();
            let mut sn = never.open(&PrecisionPlan::uniform(8)).unwrap();
            let bn = sn.begin(&x0, seed).unwrap();
            assert_eq!(
                sa.logits().data,
                sn.logits().data,
                "[{mode:?}] direct begin diverged from cached lowering (seed {seed})"
            );
            assert_eq!(
                sa.logits().data,
                one_shot(&sim, &x0, &PrecisionPlan::uniform(8), seed),
                "[{mode:?}] direct begin diverged from exact sim (seed {seed})"
            );
            assert_eq!(
                ba.executed_adds, bn.executed_adds,
                "[{mode:?}] the direct walk reorders work, it must never change it"
            );
            assert_eq!(ba.costs, bn.costs, "[{mode:?}] direct begin charge");
            assert_eq!(ba.kernel_path, KernelPath::Direct, "forced direct begin must tag Direct");
            // O(Δ) refine on top of the direct begin's caches
            let ra = sa.refine(&PrecisionPlan::uniform(32)).unwrap();
            let rn = sn.refine(&PrecisionPlan::uniform(32)).unwrap();
            assert_eq!(
                sa.logits().data,
                sn.logits().data,
                "[{mode:?}] refine after direct begin diverged (seed {seed})"
            );
            assert_eq!(ra.executed_adds, rn.executed_adds, "[{mode:?}] refine adds");
            assert!(ra.delta_updated >= 1, "[{mode:?}] delta path must engage: {ra:?}");
            // frame rebase on top of the refined state
            let za = sa.rebase_input(&x1).unwrap();
            let zn = sn.rebase_input(&x1).unwrap();
            assert_eq!(
                sa.logits().data,
                sn.logits().data,
                "[{mode:?}] rebase after direct begin diverged (seed {seed})"
            );
            assert_eq!(za.executed_adds, zn.executed_adds, "[{mode:?}] rebase adds");
            assert_eq!(za.costs, zn.costs, "[{mode:?}] rebase charge");
        }
    }
}

/// Reduction lengths whose last mask word is nearly empty: conv over
/// `cin ∈ {7, 8, 15, 29}` on 6×6 images gives kdim 63/72/135/261 —
/// tail words of 63, 8, 7 and 5 live bits across three tile-table rows.
/// Blocked (default tiles, weird odd tile overrides, and the forced
/// direct walk) must match the scalar reference bit-for-bit on each.
#[test]
fn blocked_handles_odd_tail_words_and_tile_overrides() {
    for cin in [7usize, 8, 15, 29] {
        let mut net = Network::new((6, 6, cin), "tail-words");
        let c1 = net.add(Op::Conv { k: 3, stride: 1, cin, cout: 8 }, vec![0], "c1");
        let r1 = net.add(Op::ReLU, vec![c1], "r1");
        let g = net.add(Op::GlobalAvgPool, vec![r1], "gap");
        net.add(Op::Dense { cin: 8, cout: 4 }, vec![g], "fc");
        let mut rng = Xorshift128Plus::seed_from(cin as u64);
        net.init(&mut rng);
        let mk_batch = |seed: u64, b: usize| {
            let mut rng = Xorshift128Plus::seed_from(seed);
            Tensor::from_vec(
                (0..b * 6 * 6 * cin).map(|_| rng.uniform()).collect(),
                &[b, 6, 6, cin],
            )
        };
        for s in 0..4 {
            let x = mk_batch(s, 3);
            net.forward::<Xorshift128Plus>(&x, true, None);
        }
        let psb =
            PsbNetwork::prepare(&net, PsbOptions { exact_integer: true, ..Default::default() });
        let scalar = IntKernel::new(psb.clone())
            .unwrap()
            .with_contraction(Contraction::Scalar);
        let weird = IntKernelConfig { row_tile: Some(3), col_tile: Some(5), ..Default::default() };
        let kernels = [
            IntKernel::new(psb.clone()).unwrap().with_contraction(Contraction::Blocked),
            IntKernel::new(psb.clone())
                .unwrap()
                .with_contraction(Contraction::Blocked)
                .with_config(weird)
                .with_threads(3),
            IntKernel::new(psb.clone())
                .unwrap()
                .with_contraction(Contraction::Blocked)
                .with_config(IntKernelConfig { direct_conv: DirectConv::Always, ..weird }),
        ];
        let x = mk_batch(90 + cin as u64, 3);
        for plan in [PrecisionPlan::uniform(8), PrecisionPlan::uniform(16)] {
            let want = one_shot(&scalar, &x, &plan, 5);
            for (ki, k) in kernels.iter().enumerate() {
                assert_eq!(
                    one_shot(k, &x, &plan, 5),
                    want,
                    "cin={cin} kernel[{ki}] diverged from scalar"
                );
            }
        }
    }
}

/// Depthwise graphs: the integer kernel and the `exact_integer` sim
/// produce identical logits, through one-shot passes and cached
/// refinement, on both contraction datapaths.
#[test]
fn prop_depthwise_int_kernel_matches_exact_sim() {
    let psb = PsbNetwork::prepare(
        &make_depthwise_net(),
        PsbOptions { exact_integer: true, ..Default::default() },
    );
    let sim = SimBackend::new(psb.clone());
    let scalar = IntKernel::new(psb.clone())
        .unwrap()
        .with_contraction(Contraction::Scalar);
    let packed = IntKernel::new(psb).unwrap();
    let x = batch(19, 3);
    let plans = [PrecisionPlan::uniform(8), PrecisionPlan::per_layer(&[4, 8, 16]).unwrap()];
    for seed in 0..3u64 {
        for plan in &plans {
            let want = one_shot(&sim, &x, plan, seed);
            assert_eq!(
                one_shot(&packed, &x, plan, seed),
                want,
                "depthwise packed vs exact sim: seed={seed} plan={plan:?}"
            );
            assert_eq!(
                one_shot(&scalar, &x, plan, seed),
                want,
                "depthwise scalar vs exact sim: seed={seed} plan={plan:?}"
            );
        }
        // uniform refine-from-cache (stem deltas, depthwise rebuilds on
        // its changed input) stays bit-identical to one-shot
        let direct = one_shot(&packed, &x, &PrecisionPlan::uniform(32), seed);
        for backend in [&sim as &dyn Backend, &scalar as &dyn Backend, &packed as &dyn Backend] {
            let mut sess = backend.open(&PrecisionPlan::uniform(8)).unwrap();
            sess.begin(&x, seed).unwrap();
            sess.refine(&PrecisionPlan::uniform(32)).unwrap();
            assert_eq!(
                sess.logits().data,
                direct,
                "[{}] depthwise refine 8→32 vs one-shot 32 (seed {seed})",
                backend.name()
            );
        }
        // per-layer escalation that keeps the stem fixed: the depthwise
        // node's input is clean, so it takes the O(Δ) depthwise delta
        // path — and must still match the sim doing the same escalation
        let lo = PrecisionPlan::per_layer(&[4, 4, 4]).unwrap();
        let hi = PrecisionPlan::per_layer(&[4, 16, 16]).unwrap();
        let mut sim_sess = sim.open(&lo).unwrap();
        sim_sess.begin(&x, seed).unwrap();
        sim_sess.refine(&hi).unwrap();
        for backend in [&scalar as &dyn Backend, &packed as &dyn Backend] {
            let mut sess = backend.open(&lo).unwrap();
            sess.begin(&x, seed).unwrap();
            let step = sess.refine(&hi).unwrap();
            assert!(
                step.delta_updated >= 1,
                "[{}] depthwise delta path must engage: {step:?}",
                backend.name()
            );
            assert_eq!(
                sess.logits().data,
                sim_sess.logits().data,
                "[{}] per-layer depthwise escalation diverged (seed {seed})",
                backend.name()
            );
        }
    }
}

/// Refine *execution* is O(Δ): a small escalation executes no more adds
/// than a large one from the same base, and a modest escalation executes
/// strictly less than rebuilding at the target — work follows the new
/// samples, not the total.
#[test]
fn packed_refine_executed_adds_scale_with_delta() {
    let (_, int) = backend_pair();
    let x = batch(3, 2);
    // fresh n=8 pass: every capacitor rebuilds in full
    let mut fresh = int.open(&PrecisionPlan::uniform(8)).unwrap();
    let full = fresh.begin(&x, 11).unwrap();
    // Δ4 escalation of an existing n=4 session: delta path on the first
    // capacitor, strictly less executed work than the rebuild
    let mut sess = int.open(&PrecisionPlan::uniform(4)).unwrap();
    sess.begin(&x, 11).unwrap();
    let d4 = sess.refine(&PrecisionPlan::uniform(8)).unwrap();
    assert!(d4.delta_updated >= 1, "delta path must engage: {d4:?}");
    assert!(
        d4.executed_adds < full.executed_adds,
        "Δ4 refine must execute less than a fresh n=8 pass: {} vs {}",
        d4.executed_adds,
        full.executed_adds
    );
    // Δ monotonicity from the same base: changed-weight sets are nested
    let mut s2 = int.open(&PrecisionPlan::uniform(4)).unwrap();
    s2.begin(&x, 11).unwrap();
    let d60 = s2.refine(&PrecisionPlan::uniform(64)).unwrap();
    assert!(
        d4.executed_adds < d60.executed_adds,
        "executed adds must grow with Δn: Δ4={} Δ60={}",
        d4.executed_adds,
        d60.executed_adds
    );
    // per-layer reporting covers every capacitor layer and sums up
    assert_eq!(d4.layer_adds.len(), int.network().num_capacitors);
    assert_eq!(d4.layer_adds.iter().sum::<u64>(), d4.executed_adds);
}

fn gather_rows(x: &Tensor, rows: &[usize]) -> Tensor {
    let b = x.shape[0];
    let block = x.len() / b;
    let mut data = Vec::with_capacity(rows.len() * block);
    for &r in rows {
        data.extend_from_slice(&x.data[r * block..(r + 1) * block]);
    }
    let mut shape = x.shape.clone();
    shape[0] = rows.len();
    Tensor::from_vec(data, &shape)
}

// ---- row-masked (spatial) execution -------------------------------------

/// Block mask flagging the top `frac` of each image's pixel rows — the
/// shape that survives OR-pooling through strided layers roughly intact
/// (an alternating mask would pool to all-true).
fn top_rows_mask(b: usize, h: usize, w: usize, frac: f64) -> Vec<bool> {
    let cut = ((h as f64 * frac).round() as usize).min(h);
    (0..b * h * w).map(|i| (i % (h * w)) / w < cut).collect()
}

/// Gather per-image blocks of an input-resolution mask (the `narrow`
/// companion for the plan mask).
fn gather_mask(mask: &[bool], rows: &[usize], old_b: usize) -> Vec<bool> {
    let block = mask.len() / old_b;
    let mut out = Vec::with_capacity(block * rows.len());
    for &r in rows {
        out.extend_from_slice(&mask[r * block..(r + 1) * block]);
    }
    out
}

/// Masked logits are bit-identical across the exact sim, the scalar
/// integer reference and the packed contraction at several thread
/// counts — one-shot spatial plans, mask-without-split plans, and
/// attend→refine chains; per-row billing agrees across backends too.
#[test]
fn prop_masked_int_matches_masked_exact_sim() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let scalar = IntKernel::new(net.clone())
        .unwrap()
        .with_contraction(Contraction::Scalar);
    let packed: Vec<IntKernel> = [0usize, 1, 3]
        .iter()
        .map(|&t| IntKernel::new(net.clone()).unwrap().with_threads(t))
        .collect();
    let x = batch(37, 4);
    let mask = top_rows_mask(4, 8, 8, 0.5);
    let plans = [
        PrecisionPlan::spatial(mask.clone(), 4, 16),
        // mask present but no level split: uniform execution must still
        // propagate regions identically
        PrecisionPlan::per_layer(&[4, 8, 16]).unwrap().with_mask(mask.clone()),
    ];
    for seed in 0..3u64 {
        for plan in &plans {
            let want = one_shot(&sim, &x, plan, seed);
            assert_eq!(
                one_shot(&scalar, &x, plan, seed),
                want,
                "scalar masked vs exact sim: seed={seed}"
            );
            for (pi, p) in packed.iter().enumerate() {
                assert_eq!(
                    one_shot(p, &x, plan, seed),
                    want,
                    "packed[{pi}] masked vs exact sim: seed={seed}"
                );
            }
        }
        // the attend→refine loop: uniform stage 1, masked escalation,
        // deeper masked escalation — the tentpole path
        let s2 = PrecisionPlan::spatial(mask.clone(), 4, 8);
        let s3 = PrecisionPlan::spatial(mask.clone(), 8, 32);
        let chain = |backend: &dyn Backend| {
            let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&x, seed).unwrap();
            sess.refine(&s2).unwrap();
            sess.refine(&s3).unwrap();
            (sess.logits().data.clone(), sess.cost_report().total.gated_adds)
        };
        let (want, want_adds) = chain(&sim);
        let (got, got_adds) = chain(&scalar);
        assert_eq!(got, want, "scalar masked chain diverged (seed {seed})");
        assert_eq!(got_adds, want_adds, "per-row billing must agree across backends");
        for (pi, p) in packed.iter().enumerate() {
            let (got, got_adds) = chain(p);
            assert_eq!(got, want, "packed[{pi}] masked chain diverged (seed {seed})");
            assert_eq!(got_adds, want_adds, "packed[{pi}] billing diverged (seed {seed})");
        }
    }
}

/// Masks survive `narrow`: a masked session narrowed to a row subset and
/// escalated again equals the narrowed-from-birth reference on both
/// backends, and the backends agree with each other.
#[test]
fn masked_sessions_survive_narrow_bit_identically() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let int = IntKernel::new(net).unwrap();
    let x = batch(41, 4);
    let mask4 = top_rows_mask(4, 8, 8, 0.5);
    let rows = [0usize, 2];
    let xr = gather_rows(&x, &rows);
    let maskr = gather_mask(&mask4, &rows, 4);
    let mut finals = Vec::new();
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        sess.begin(&x, 6).unwrap();
        sess.refine(&PrecisionPlan::spatial(mask4.clone(), 4, 8)).unwrap();
        sess.narrow(&rows).unwrap();
        sess.refine(&PrecisionPlan::spatial(maskr.clone(), 8, 16)).unwrap();
        let mut reference = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        reference.begin(&xr, 6).unwrap();
        reference.refine(&PrecisionPlan::spatial(maskr.clone(), 4, 8)).unwrap();
        reference.refine(&PrecisionPlan::spatial(maskr.clone(), 8, 16)).unwrap();
        assert_eq!(
            sess.logits().data,
            reference.logits().data,
            "[{}] mask must survive narrow",
            backend.name()
        );
        assert_eq!(sess.logits().shape, vec![2, 4]);
        finals.push(sess.logits().data.clone());
    }
    assert_eq!(finals[0], finals[1], "backends diverged on the narrowed masked chain");
}

/// Masked *depthwise* graphs: spatial plans on the integer kernel match
/// the exact sim, and the two-stage charges partition the one-shot
/// charge exactly (no `mask_fraction()` estimate).
#[test]
fn masked_depthwise_matches_exact_sim_and_bills_exactly() {
    let psb = PsbNetwork::prepare(
        &make_depthwise_net(),
        PsbOptions { exact_integer: true, ..Default::default() },
    );
    let sim = SimBackend::new(psb.clone());
    let int = IntKernel::new(psb).unwrap();
    let x = batch(19, 3);
    let mask = top_rows_mask(3, 8, 8, 0.5);
    let spatial = PrecisionPlan::spatial(mask.clone(), 4, 16);
    for seed in 0..3u64 {
        let want = one_shot(&sim, &x, &spatial, seed);
        assert_eq!(one_shot(&int, &x, &spatial, seed), want, "masked depthwise (seed {seed})");
    }
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut fresh = backend.open(&spatial).unwrap();
        let full = fresh.begin(&x, 8).unwrap();
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        let a = sess.begin(&x, 8).unwrap();
        let b = sess.refine(&spatial).unwrap();
        assert_eq!(
            a.costs.gated_adds + b.costs.gated_adds,
            full.costs.gated_adds,
            "[{}] masked depthwise stage charges must partition the one-shot charge",
            backend.name()
        );
        assert_eq!(sess.logits().data, fresh.logits().data);
    }
}

/// The spatial-collapse accounting fix: charges partition the one-shot
/// charge exactly through uniform → spatial → uniform chains, because
/// each row is billed its own increment against the region its cached
/// result holds (previously the collapse re-billed attended rows at the
/// base increment).
#[test]
fn stage_charges_partition_through_split_and_collapse() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let int = IntKernel::new(net).unwrap();
    let x = batch(9, 2);
    let mask = top_rows_mask(2, 8, 8, 0.5);
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut fresh = backend.open(&PrecisionPlan::uniform(16)).unwrap();
        let full = fresh.begin(&x, 4).unwrap();
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        let a = sess.begin(&x, 4).unwrap();
        let b = sess.refine(&PrecisionPlan::spatial(mask.clone(), 4, 16)).unwrap();
        let c = sess.refine(&PrecisionPlan::uniform(16)).unwrap();
        assert_eq!(
            a.costs.gated_adds + b.costs.gated_adds + c.costs.gated_adds,
            full.costs.gated_adds,
            "[{}] split collapse must re-bill per row",
            backend.name()
        );
        assert_eq!(
            sess.logits().data,
            fresh.logits().data,
            "[{}] collapse chain must equal the one-shot pass",
            backend.name()
        );
    }
}

/// The whole two-stage attention pipeline is backend-generic and
/// bit-identical across backends: identical stage-1 logits ⇒ identical
/// entropy masks ⇒ identical spatial plans ⇒ identical refined logits
/// and identical per-row charges.
#[test]
fn adaptive_attention_is_bit_identical_across_backends() {
    let (sim, int) = backend_pair();
    let x = batch(29, 3);
    let a = psb::attention::adaptive_forward(&sim, &x, 4, 16, 9);
    let b = psb::attention::adaptive_forward(&int, &x, 4, 16, 9);
    assert_eq!(a.logits.data, b.logits.data, "attention logits diverged across backends");
    assert!((a.interesting_fraction - b.interesting_fraction).abs() < 1e-9);
    assert_eq!(
        a.costs.gated_adds, b.costs.gated_adds,
        "per-row progressive charges must agree across backends"
    );
}

/// A 35% block mask executes ≤ ~(0.35 + ε) of the full-plan adds on the
/// high-precision increment: base-track rows finish early at `n_low`,
/// only attended rows (plus their conv halo) execute — the measured
/// form of the paper's −33% claim (ε covers the halo and the dense
/// head, which always rebuilds).
#[test]
fn masked_refine_executed_adds_track_the_mask_fraction() {
    // 32×32 serving CNN: large enough that the attended halo stays small
    let mut rng = Xorshift128Plus::seed_from(11);
    let mut net = psb::models::serving_cnn(&mut rng);
    let batch32 = |seed: u64, b: usize| {
        let mut rng = Xorshift128Plus::seed_from(seed);
        Tensor::from_vec(
            (0..b * 32 * 32 * 3).map(|_| rng.uniform()).collect(),
            &[b, 32, 32, 3],
        )
    };
    for s in 0..6 {
        let x = batch32(s, 4);
        net.forward::<Xorshift128Plus>(&x, true, None);
    }
    let psb = PsbNetwork::prepare(&net, PsbOptions { exact_integer: true, ..Default::default() });
    let int = IntKernel::new(psb).unwrap();
    let x = batch32(100, 2);
    let frac = 0.35;
    let mask = top_rows_mask(2, 32, 32, frac);
    let mut s_full = int.open(&PrecisionPlan::uniform(8)).unwrap();
    s_full.begin(&x, 3).unwrap();
    let mut s_masked = s_full.fork().unwrap();
    let full = s_full.refine(&PrecisionPlan::uniform(16)).unwrap();
    let masked = s_masked.refine(&PrecisionPlan::spatial(mask, 8, 16)).unwrap();
    let ratio = masked.executed_adds as f64 / full.executed_adds.max(1) as f64;
    assert!(
        ratio <= frac + 0.15,
        "masked refine executed {:.0}% of the full-plan increment (want ≤ {:.0}%)",
        ratio * 100.0,
        (frac + 0.15) * 100.0
    );
    // the charge shrinks with the mask too: only attended rows pay the
    // increment
    assert!(
        masked.costs.gated_adds < full.costs.gated_adds / 2,
        "masked increment charge {} vs full {}",
        masked.costs.gated_adds,
        full.costs.gated_adds
    );
}

// ---- pooled / merged sessions -------------------------------------------

/// The engine's merge contract, at the backend level: N independent
/// sessions (distinct inputs, distinct seeds, stage-2-style narrows)
/// merged via `Backend::merge_sessions` and refined as ONE dispatch must
/// produce, per part, the same logits and the same exact per-row charges
/// as N serial sessions — on both backends, at any thread count, through
/// uniform AND masked (spatial) refinement chains.
#[test]
fn prop_merged_sessions_refine_bit_identically_to_serial() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let int_1t = IntKernel::new(net.clone()).unwrap().with_threads(1);
    let int_mt = IntKernel::new(net).unwrap().with_threads(5);
    let backends: [(&str, &dyn Backend); 3] =
        [("sim", &sim), ("int-1t", &int_1t), ("int-5t", &int_mt)];

    // three parts: different inputs, different seeds, stage-2-shaped
    // narrows (None = whole batch)
    let xs = [batch(101, 2), batch(202, 2), batch(303, 2)];
    let seeds = [11u64, 22, 33];
    let narrows: [Option<Vec<usize>>; 3] = [None, Some(vec![0]), Some(vec![1, 0])];
    // chain: uniform 4 → uniform 8 → spatial (8, 16) over the top rows
    let mask_for = |rows: usize| top_rows_mask(rows, 8, 8, 0.5);
    let open_part = |backend: &dyn Backend, i: usize| {
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        sess.begin(&xs[i], seeds[i]).unwrap();
        if let Some(rows) = &narrows[i] {
            sess.narrow(rows).unwrap();
        }
        sess
    };
    for (bname, backend) in backends {
        // serial oracle: each part (its own input, seed and narrow)
        // refined on its own, 4 → 8 → 16
        let mut serial_logits: Vec<Vec<f32>> = Vec::new();
        let mut serial_steps: Vec<Vec<psb::backend::StepReport>> = Vec::new();
        for i in 0..3 {
            let mut sess = open_part(backend, i);
            let s8 = sess.refine(&PrecisionPlan::uniform(8)).unwrap();
            let s16 = sess.refine(&PrecisionPlan::uniform(16)).unwrap();
            serial_steps.push(vec![s8, s16]);
            serial_logits.push(sess.logits().data.clone());
        }
        // merged: same parts, ONE dispatch per refinement step
        let parts: Vec<Box<dyn InferenceSession>> =
            (0..3).map(|i| open_part(backend, i)).collect();
        let part_rows: Vec<usize> =
            parts.iter().map(|p| p.logits().shape[0]).collect();
        let mut merged = match backend.merge_sessions(parts).unwrap() {
            psb::backend::MergeOutcome::Merged(m) => m,
            psb::backend::MergeOutcome::Unsupported(_) => {
                panic!("[{bname}] stateful backend must merge same-plan sessions")
            }
        };
        assert_eq!(merged.part_rows(), part_rows, "[{bname}] part extents");
        for (step_idx, target) in
            [PrecisionPlan::uniform(8), PrecisionPlan::uniform(16)].iter().enumerate()
        {
            merged.refine(target).unwrap();
            let steps = merged.part_steps();
            assert_eq!(steps.len(), 3, "[{bname}] one step report per part");
            for i in 0..3 {
                assert_eq!(
                    steps[i].costs, serial_steps[i][step_idx].costs,
                    "[{bname}] part {i} charge of merged step {step_idx} must equal serial"
                );
                assert_eq!(
                    steps[i].executed_adds, serial_steps[i][step_idx].executed_adds,
                    "[{bname}] part {i} executed work of merged step {step_idx} must equal serial"
                );
            }
        }
        // the merged logits are the serial logits, concatenated in part
        // order — nothing about a part depends on its pool position
        let want: Vec<f32> = serial_logits.concat();
        assert_eq!(
            merged.logits().data, want,
            "[{bname}] merged 4→8→16 logits must equal the serial concatenation"
        );
        // masked chains go through the merged session too when parts
        // share geometry: verify against two equal-extent parts
        let eq_parts: Vec<Box<dyn InferenceSession>> = (0..2)
            .map(|i| {
                let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
                sess.begin(&xs[i], seeds[i]).unwrap();
                sess.refine(&PrecisionPlan::uniform(8)).unwrap();
                sess
            })
            .collect();
        let mut eq_merged = match backend.merge_sessions(eq_parts).unwrap() {
            psb::backend::MergeOutcome::Merged(m) => m,
            psb::backend::MergeOutcome::Unsupported(_) => panic!("[{bname}] must merge"),
        };
        let masked_target = PrecisionPlan::spatial(mask_for(2), 8, 16);
        eq_merged.refine(&masked_target).unwrap();
        let eq_steps = eq_merged.part_steps();
        let mut serial_cat = Vec::new();
        for i in 0..2 {
            let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&xs[i], seeds[i]).unwrap();
            sess.refine(&PrecisionPlan::uniform(8)).unwrap();
            let step = sess.refine(&masked_target).unwrap();
            assert_eq!(
                eq_steps[i].costs, step.costs,
                "[{bname}] masked merged charge (part {i}) must equal serial"
            );
            serial_cat.extend_from_slice(&sess.logits().data);
        }
        assert_eq!(
            eq_merged.logits().data, serial_cat,
            "[{bname}] masked merged logits must be the serial concatenation"
        );
    }
}

// ---- temporal delta rebase ----------------------------------------------

/// `rebase_input` contract, property style: after any refinement chain,
/// rebasing a session onto a new frame yields **logits and per-row
/// charges bit-identical to a fresh `begin(new_frame, seed)`** at the
/// session's current plan — on the exact sim (full-recompute reference)
/// and the IntKernel's O(Δ) path (scalar and packed, several thread
/// counts), for partially-changed, fully-changed and identical frames,
/// and across chained rebases.
#[test]
fn prop_rebase_matches_fresh_begin_bit_identically() {
    let net = prepared(PsbOptions { exact_integer: true, ..Default::default() });
    let sim = SimBackend::new(net.clone());
    let scalar = IntKernel::new(net.clone())
        .unwrap()
        .with_contraction(Contraction::Scalar);
    let packed: Vec<IntKernel> = [0usize, 1, 3]
        .iter()
        .map(|&t| IntKernel::new(net.clone()).unwrap().with_threads(t))
        .collect();
    let mut backends: Vec<(String, &dyn Backend)> =
        vec![("sim".into(), &sim), ("int-scalar".into(), &scalar)];
    for (i, p) in packed.iter().enumerate() {
        backends.push((format!("int-packed-t{}", [0, 1, 3][i]), p));
    }
    let seed = 17u64;
    let x0 = batch(61, 2);
    // partial frame: image 0's top two pixel rows drift, image 1 is
    // untouched (rebase must not disturb the clean image's rows)
    let mut x_part = x0.clone();
    for v in x_part.data[..2 * 8 * 3].iter_mut() {
        *v += 0.25;
    }
    let x_full = batch(62, 2);
    let mask = top_rows_mask(2, 8, 8, 0.5);
    // (chain of refines after begin(uniform 4), the plan the session
    // ends at — the plan a fresh reference session must open with)
    let chains: Vec<(Vec<PrecisionPlan>, PrecisionPlan)> = vec![
        (vec![], PrecisionPlan::uniform(4)),
        (vec![PrecisionPlan::uniform(8)], PrecisionPlan::uniform(8)),
        (
            vec![PrecisionPlan::spatial(mask.clone(), 4, 8)],
            PrecisionPlan::spatial(mask.clone(), 4, 8),
        ),
    ];
    for (chain, final_plan) in &chains {
        let mut cross: Vec<Vec<f32>> = Vec::new();
        for (bname, backend) in &backends {
            let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
            sess.begin(&x0, seed).unwrap();
            for target in chain {
                sess.refine(target).unwrap();
            }
            for (fname, frame) in
                [("partial", &x_part), ("full", &x_full), ("identical", &x0)]
            {
                let mut fork = sess.fork().unwrap();
                let step = fork.rebase_input(frame).unwrap();
                let mut fresh = backend.open(final_plan).unwrap();
                let fresh_step = fresh.begin(frame, seed).unwrap();
                assert_eq!(
                    fork.logits().data,
                    fresh.logits().data,
                    "[{bname}] {fname} rebase logits must equal a fresh begin"
                );
                assert_eq!(
                    step.costs, fresh_step.costs,
                    "[{bname}] {fname} rebase must bill exactly a fresh pass"
                );
            }
            // chained rebases: frame k's state rebases onto frame k+1
            sess.rebase_input(&x_part).unwrap();
            sess.rebase_input(&x_full).unwrap();
            let mut fresh = backend.open(final_plan).unwrap();
            fresh.begin(&x_full, seed).unwrap();
            assert_eq!(
                sess.logits().data,
                fresh.logits().data,
                "[{bname}] chained rebases must equal a fresh begin on the last frame"
            );
            cross.push(sess.logits().data.clone());
        }
        for (i, got) in cross.iter().enumerate() {
            assert_eq!(got, &cross[0], "backend {i} diverged from backend 0 after rebases");
        }
    }
}

/// Rebased sessions keep refining: escalate after a rebase and the
/// logits equal a fresh begin + refine on the new frame — the streaming
/// serve loop's rebase → (maybe) escalate cycle is exact.
#[test]
fn rebased_sessions_refine_bit_identically() {
    let (sim, int) = backend_pair();
    let x0 = batch(71, 2);
    let x1 = batch(72, 2);
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        sess.begin(&x0, 9).unwrap();
        sess.rebase_input(&x1).unwrap();
        sess.refine(&PrecisionPlan::uniform(16)).unwrap();
        let mut fresh = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        fresh.begin(&x1, 9).unwrap();
        fresh.refine(&PrecisionPlan::uniform(16)).unwrap();
        assert_eq!(
            sess.logits().data,
            fresh.logits().data,
            "[{}] refine after rebase must equal begin + refine on the new frame",
            backend.name()
        );
    }
}

/// The point of the rebase: executed work is O(changed rows + halo),
/// not O(frame).  An identical frame executes zero adds (while still
/// billing the full fresh-pass charge), and a ~5%-changed frame on the
/// 32×32 serving CNN executes a small fraction of a fresh pass.
#[test]
fn rebase_executed_adds_scale_with_changed_fraction() {
    let mut rng = Xorshift128Plus::seed_from(11);
    let mut net = psb::models::serving_cnn(&mut rng);
    let batch32 = |seed: u64, b: usize| {
        let mut rng = Xorshift128Plus::seed_from(seed);
        Tensor::from_vec(
            (0..b * 32 * 32 * 3).map(|_| rng.uniform()).collect(),
            &[b, 32, 32, 3],
        )
    };
    for s in 0..6 {
        let x = batch32(s, 4);
        net.forward::<Xorshift128Plus>(&x, true, None);
    }
    let psb = PsbNetwork::prepare(&net, PsbOptions { exact_integer: true, ..Default::default() });
    let int = IntKernel::new(psb).unwrap();
    let x0 = batch32(100, 2);
    let mut sess = int.open(&PrecisionPlan::uniform(8)).unwrap();
    sess.begin(&x0, 3).unwrap();
    let mut fresh = int.open(&PrecisionPlan::uniform(8)).unwrap();
    let fresh_step = fresh.begin(&x0, 3).unwrap();
    // identical frame: all-rows reuse — zero executed adds, full charge
    let mut same = sess.fork().unwrap();
    let same_step = same.rebase_input(&x0).unwrap();
    assert_eq!(same_step.executed_adds, 0, "identical frame must execute nothing");
    assert_eq!(same_step.costs, fresh_step.costs, "…while billing a full fresh pass");
    assert_eq!(same.logits().data, fresh.logits().data);
    // drift the top 2 of 32 pixel rows (~6% of the frame) in both images
    let frac = 2.0 / 32.0;
    let mut x1 = x0.clone();
    let img = 32 * 32 * 3;
    for b in 0..2 {
        for v in x1.data[b * img..b * img + 2 * 32 * 3].iter_mut() {
            *v += 0.3;
        }
    }
    let step = sess.rebase_input(&x1).unwrap();
    let direct = one_shot(&int, &x1, &PrecisionPlan::uniform(8), 3);
    assert_eq!(sess.logits().data, direct, "delta rebase must stay exact");
    let ratio = step.executed_adds as f64 / fresh_step.executed_adds.max(1) as f64;
    assert!(
        ratio <= frac + 0.25,
        "rebase of a {:.0}%-changed frame executed {:.0}% of a fresh pass (want ≤ {:.0}%; \
         ε covers the conv halo and the always-rebuilt dense head)",
        frac * 100.0,
        ratio * 100.0,
        (frac + 0.25) * 100.0
    );
    assert_eq!(step.costs, fresh_step.costs, "rebase bills as a fresh pass");
}

/// Rebase guards: geometry changes are rejected with the session
/// intact, and rebase before begin errors by name.
#[test]
fn rebase_rejects_bad_frames_loudly() {
    let (sim, int) = backend_pair();
    let x = batch(81, 2);
    for backend in [&sim as &dyn Backend, &int as &dyn Backend] {
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        assert!(
            sess.rebase_input(&x).is_err(),
            "[{}] rebase before begin must error",
            backend.name()
        );
        sess.begin(&x, 2).unwrap();
        let before = sess.logits().data.clone();
        let wrong = batch(81, 3); // batch extent changed
        assert!(
            sess.rebase_input(&wrong).is_err(),
            "[{}] geometry change must be rejected",
            backend.name()
        );
        // the rejection is a no-op: the session still serves and refines
        assert_eq!(sess.logits().data, before, "[{}] reject is a no-op", backend.name());
        sess.refine(&PrecisionPlan::uniform(8)).unwrap();
    }
}

/// Merging rejects what it cannot keep bit-identical: mismatched plans
/// hand the sessions back untouched, and the parts keep serving.
#[test]
fn merge_rejects_mismatched_plans_and_returns_sessions() {
    let (_, int) = backend_pair();
    let x = batch(5, 2);
    let mut a = int.open(&PrecisionPlan::uniform(4)).unwrap();
    a.begin(&x, 1).unwrap();
    let mut b = int.open(&PrecisionPlan::uniform(8)).unwrap();
    b.begin(&x, 2).unwrap();
    let direct_a = a.logits().data.clone();
    match int.merge_sessions(vec![a, b]).unwrap() {
        psb::backend::MergeOutcome::Merged(_) => {
            panic!("sessions at different plans must not merge")
        }
        psb::backend::MergeOutcome::Unsupported(mut parts) => {
            assert_eq!(parts.len(), 2, "both sessions hand back");
            // the returned sessions are intact and still refine
            assert_eq!(parts[0].logits().data, direct_a);
            parts[0].refine(&PrecisionPlan::uniform(8)).unwrap();
        }
    }
}
