//! Integration: rust simulator ⇄ AOT artifacts (PJRT) round trips.
//!
//! Requires `make artifacts`; every test skips (with a loud message) when
//! the artifact directory is absent so `cargo test` stays runnable on a
//! fresh checkout.

use psb::data::{Dataset, SynthConfig};
use psb::rng::Xorshift128Plus;
use psb::runtime::{ArtifactMeta, FloatBundle, PsbBundle, Runtime};
use psb::sim::layers::argmax_rows;
use psb::sim::train::{train, TrainConfig};

const SERVING_SHAPES: [[usize; 2]; 4] = [[27, 16], [144, 32], [288, 32], [32, 10]];

fn artifacts() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("meta.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/meta.txt missing — run `make artifacts`");
        None
    }
}

fn trained() -> (psb::sim::network::Network, Dataset) {
    let data = Dataset::synth(&SynthConfig {
        train: 512,
        test: 128,
        size: 32,
        seed: 42,
        ..Default::default()
    });
    let mut rng = Xorshift128Plus::seed_from(42);
    let mut net = psb::models::serving_cnn(&mut rng);
    train(&mut net, &data, &TrainConfig { epochs: 2, ..Default::default() });
    (net, data)
}

#[test]
fn meta_parses_and_lists_modules() {
    let Some(dir) = artifacts() else { return };
    let meta = ArtifactMeta::load(&dir).unwrap();
    assert_eq!(meta.image, 32);
    assert_eq!(meta.num_classes, 10);
    assert_eq!(meta.layer_shapes.len(), 4);
    assert_eq!(meta.layer_shapes[2].weight, [288, 32]);
    for b in &meta.batches {
        assert!(meta.modules.contains_key(&meta.float_module(*b)));
        for n in &meta.sample_sizes {
            let m = &meta.modules[&meta.psb_module(*n, *b)];
            assert_eq!(m.kind, "psb");
            assert_eq!(m.n, Some(*n));
        }
    }
}

#[test]
fn float_module_matches_simulator() {
    let Some(dir) = artifacts() else { return };
    let (mut net, data) = trained();
    let float = FloatBundle::from_network(&net, &SERVING_SHAPES).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let (x, _) = data.gather_test(&(0..8).collect::<Vec<_>>());
    let exec = rt.run_float(8, &x.data, &float).unwrap();
    let sim = net.forward::<Xorshift128Plus>(&x, false, None);
    let max_err = exec
        .logits
        .iter()
        .zip(&sim.logits().data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // same graph, different op ordering: small fp slack
    assert!(max_err < 5e-3, "PJRT float vs rust sim: max err {max_err}");
    assert_eq!(exec.feat_shape, [8, 8, 8, 32]);
}

#[test]
fn psb_module_converges_to_float_with_n() {
    let Some(dir) = artifacts() else { return };
    let (mut net, data) = trained();
    let float = FloatBundle::from_network(&net, &SERVING_SHAPES).unwrap();
    let psb = PsbBundle::from_float(&float, None);
    let mut rt = Runtime::new(&dir).unwrap();
    let (x, _) = data.gather_test(&(0..8).collect::<Vec<_>>());
    let ref_logits = net.forward::<Xorshift128Plus>(&x, false, None).logits().clone();
    let mut errs = Vec::new();
    for n in [1u32, 8, 64] {
        let exec = rt.run_psb(n, 8, &x.data, 7, &psb).unwrap();
        let err: f32 = exec
            .logits
            .iter()
            .zip(&ref_logits.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / exec.logits.len() as f32;
        errs.push(err);
    }
    assert!(errs[2] < errs[0], "PSB error must fall with n: {errs:?}");
    assert!(errs[2] < 0.25, "psb64 too far from float: {errs:?}");
}

#[test]
fn psb_module_is_deterministic_per_seed() {
    let Some(dir) = artifacts() else { return };
    let (net, data) = trained();
    let psb = PsbBundle::from_network(&net, &SERVING_SHAPES, None).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let (x, _) = data.gather_test(&[0]);
    let a = rt.run_psb(8, 1, &x.data, 123, &psb).unwrap();
    let b = rt.run_psb(8, 1, &x.data, 123, &psb).unwrap();
    assert_eq!(a.logits, b.logits, "same seed must reproduce exactly");
    let c = rt.run_psb(8, 1, &x.data, 124, &psb).unwrap();
    assert_ne!(a.logits, c.logits, "different seed must differ");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(dir) = artifacts() else { return };
    let (net, data) = trained();
    let psb = PsbBundle::from_network(&net, &SERVING_SHAPES, None).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let (x, _) = data.gather_test(&[0]);
    for _ in 0..3 {
        rt.run_psb(8, 1, &x.data, 1, &psb).unwrap();
    }
    assert_eq!(rt.compiles, 1);
    rt.run_psb(16, 1, &x.data, 1, &psb).unwrap();
    assert_eq!(rt.compiles, 2);
}

#[test]
fn psb_argmax_tracks_float_at_high_n() {
    let Some(dir) = artifacts() else { return };
    let (mut net, data) = trained();
    let float = FloatBundle::from_network(&net, &SERVING_SHAPES).unwrap();
    let psb = PsbBundle::from_float(&float, None);
    let mut rt = Runtime::new(&dir).unwrap();
    let (x, _) = data.gather_test(&(0..8).collect::<Vec<_>>());
    let sim = net.forward::<Xorshift128Plus>(&x, false, None);
    let want = argmax_rows(&sim.logits().data, 10);
    let exec = rt.run_psb(64, 8, &x.data, 5, &psb).unwrap();
    let got = argmax_rows(&exec.logits, 10);
    let agree = got.iter().zip(&want).filter(|(a, b)| a == b).count();
    assert!(agree >= 6, "psb64 argmax agreement {agree}/8");
}
