//! Chaos properties: the supervised serving stack under a seeded fault
//! schedule (`backend::chaos`).  Three contracts, per docs/ROBUSTNESS.md:
//!
//! 1. **No dropped replies** — under any fault mix every submitted
//!    request yields exactly one reply: an answer or a named error,
//!    never a hung or silently closed channel.
//! 2. **Recovery is bit-exact** — a retried begin, a resurrected
//!    escalation, and a resurrected stream frame reproduce a
//!    never-faulted oracle's logits *and* charged billing exactly
//!    (PSB sessions are pure functions of `(plan, seed, input)`).
//! 3. **Degradation is explicit** — when recovery is impossible the
//!    reply says so (`ServedVia::Degraded` with `escalated == false`,
//!    or a named error), and the fault counters account for it.
//!
//! The schedule seed comes from `PSB_CHAOS_SEED` (CI's `chaos-smoke`
//! job sweeps several); every test appends its outcome tallies to
//! `CHAOS_transcript.txt`, which CI uploads on failure.

use std::sync::{Arc, Mutex, Once};
use std::time::Duration;

use psb::backend::{chaos_factory, sim_factory, ChaosConfig};
use psb::coordinator::{
    is_overloaded, BatcherConfig, BrownoutConfig, Clock, Coordinator, CoordinatorConfig, Engine,
    EscalationPolicy, ServedVia, Supervisor, SupervisorConfig,
};
use psb::precision::PrecisionPlan;
use psb::rng::{RngKind, Xorshift128Plus};
use psb::sim::network::{Network, Op};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};

const IMG: usize = 8 * 8 * 3;
const NC: usize = 2;

fn tiny_psbnet() -> PsbNetwork {
    let mut net = Network::new((8, 8, 3), "chaos-test");
    let c1 = net.add(Op::Conv { k: 3, stride: 2, cin: 3, cout: 4 }, vec![0], "c1");
    let r1 = net.add(Op::ReLU, vec![c1], "r1");
    net.feat_node = Some(r1);
    let g = net.add(Op::GlobalAvgPool, vec![r1], "gap");
    net.add(Op::Dense { cin: 4, cout: NC }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(3);
    net.init(&mut rng);
    PsbNetwork::prepare(&net, PsbOptions::default())
}

/// Schedule seed under test — CI's chaos-smoke matrix sets this.
fn chaos_seed() -> u64 {
    std::env::var("PSB_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}

fn image(tag: f32) -> Vec<f32> {
    (0..IMG).map(|i| ((i as f32) * 0.013 + tag).sin() * 0.5).collect()
}

// ------------------------------------------------------------ transcript

static TRANSCRIPT_LOCK: Mutex<()> = Mutex::new(());
static TRANSCRIPT_INIT: Once = Once::new();

/// Append a test's outcome tallies to `CHAOS_transcript.txt` (truncated
/// once per run).  Written *before* the asserts, so a red run's artifact
/// shows what the schedule actually did.
fn transcript(section: &str, lines: &[String]) {
    use std::io::Write as _;
    let _g = TRANSCRIPT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/CHAOS_transcript.txt");
    TRANSCRIPT_INIT.call_once(|| {
        let _ = std::fs::remove_file(path);
    });
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "== {section} (PSB_CHAOS_SEED={}) ==", chaos_seed());
        for l in lines {
            let _ = writeln!(f, "  {l}");
        }
    }
}

fn stat(v: &std::sync::atomic::AtomicU64) -> u64 {
    v.load(std::sync::atomic::Ordering::Relaxed)
}

/// A supervisor that never gives up early: huge deadline, generous
/// retries, breaker effectively disabled, virtual clock (backoff and
/// deadlines advance instantly).  The bit-exactness tests want recovery
/// to *run*, not to be rationed.
fn patient_supervisor(engine: &Arc<Engine>) -> Supervisor {
    Supervisor::new(
        engine.clone(),
        Clock::virtual_clock(),
        SupervisorConfig {
            deadline: Duration::from_secs(3600),
            max_retries: 12,
            backoff_base: Duration::from_millis(5),
            breaker_threshold: 1_000_000,
            breaker_cooldown: Duration::ZERO,
        },
        NC,
    )
}

// -------------------------------------------------- bit-exact recovery

/// Escalations recovered by retry/resurrection answer bit-identically —
/// logits AND charged billing — to a never-faulted oracle running the
/// same `(plan, x, batch, seed)` begins and the same narrowed refines.
#[test]
fn resurrected_escalations_match_a_never_faulted_oracle() {
    const TRIALS: u64 = 24;
    const BATCH: usize = 3;
    let plan_low = PrecisionPlan::uniform(4);
    let plan_high = PrecisionPlan::uniform(16);
    let rows = vec![0usize, 2];

    // the oracle: same ops, no chaos decorator
    let oracle = Engine::spawn(sim_factory(tiny_psbnet(), RngKind::Xorshift)).unwrap();
    let mut expect = Vec::new();
    for t in 0..TRIALS {
        let x: Vec<f32> = (0..BATCH).flat_map(|r| image(t as f32 + r as f32 * 0.31)).collect();
        let b = oracle.begin_session(plan_low.clone(), x, BATCH, t).unwrap();
        let id = b.session.expect("oracle begin keeps a session");
        let r = oracle.refine_session(id, Some(rows.clone()), plan_high.clone()).unwrap();
        expect.push((b.exec.logits, b.gated_adds, r.exec.logits, r.gated_adds));
    }

    let cfg = ChaosConfig {
        seed: chaos_seed(),
        transient_permille: 250,
        permanent_permille: 20,
        slow_permille: 0,
        poison_permille: 60,
        geometry_permille: 40,
        slow_op: Duration::ZERO,
    };
    let (factory, _stats) = chaos_factory(sim_factory(tiny_psbnet(), RngKind::Xorshift), cfg);
    let engine = Arc::new(Engine::spawn(factory).unwrap());
    let sup = patient_supervisor(&engine);

    let mut begins_ok = 0u64;
    let mut refines_ok = 0u64;
    let mut refines_err = 0u64;
    for t in 0..TRIALS {
        let x: Vec<f32> = (0..BATCH).flat_map(|r| image(t as f32 + r as f32 * 0.31)).collect();
        let (want_bl, want_bg, want_rl, want_rg) = &expect[t as usize];
        let (out, _recovered) = sup
            .begin_session(plan_low.clone(), x, BATCH, t)
            .expect("a begin is stateless: bounded retry must absorb transient faults");
        assert_eq!(&out.exec.logits, want_bl, "trial {t}: begin logits drifted under chaos");
        assert_eq!(out.gated_adds, *want_bg, "trial {t}: begin billing drifted under chaos");
        begins_ok += 1;
        let id = out.session.expect("supervised begin keeps a session");
        match sup.submit_refine(id, rows.clone(), plan_high.clone()).and_then(|tk| sup.await_refine(tk)) {
            Ok((r, _resurrected)) => {
                assert_eq!(&r.exec.logits, want_rl, "trial {t}: refine logits drifted under chaos");
                assert_eq!(r.gated_adds, *want_rg, "trial {t}: refine billing drifted under chaos");
                refines_ok += 1;
            }
            Err(e) => {
                // only a (permanent)-marked fault may end an escalation
                // under this patient config — and it must say so
                let msg = format!("{e:#}");
                assert!(msg.contains("supervised refine failed"), "unnamed failure: {msg}");
                assert!(msg.contains("(permanent)"), "gave up on a retryable fault: {msg}");
                refines_err += 1;
            }
        }
    }
    let st = sup.stats();
    transcript(
        "resurrected_escalations_match_a_never_faulted_oracle",
        &[
            format!("begins_ok={begins_ok} refines_ok={refines_ok} refines_err={refines_err}"),
            format!(
                "faults_seen={} retries={} resurrections={}",
                stat(&st.faults_seen),
                stat(&st.retries),
                stat(&st.resurrections)
            ),
        ],
    );
    assert_eq!(begins_ok, TRIALS);
    assert!(refines_ok >= TRIALS / 2, "most escalations must complete: {refines_ok}/{TRIALS}");
    assert!(stat(&st.faults_seen) > 0, "a 37% fault mix must fault somewhere in {TRIALS} trials");
    assert!(
        stat(&st.resurrections) >= 1,
        "some refine fault must have forced a resurrection (faults_seen={})",
        stat(&st.faults_seen)
    );
}

/// Stream frames recovered through the rebase contract — a resurrected
/// session is a fresh `begin` on the new frame — are bit-identical in
/// logits and charged billing to an oracle running a fresh pass per
/// frame (which is exactly what `rebase_input` bills as).
#[test]
fn resurrected_stream_frames_match_the_oracle() {
    const FRAMES: u64 = 32;
    const SEED: u64 = 91;
    let plan = PrecisionPlan::uniform(8);

    let oracle = Engine::spawn(sim_factory(tiny_psbnet(), RngKind::Xorshift)).unwrap();
    let mut expect = Vec::new();
    for f in 0..FRAMES {
        let out = oracle.run_once(plan.clone(), image(f as f32 * 0.1), 1, SEED).unwrap();
        expect.push((out.exec.logits, out.gated_adds));
    }

    let cfg = ChaosConfig {
        seed: chaos_seed().wrapping_add(1),
        transient_permille: 200,
        permanent_permille: 15,
        slow_permille: 0,
        poison_permille: 50,
        geometry_permille: 35,
        slow_op: Duration::ZERO,
    };
    let (factory, _stats) = chaos_factory(sim_factory(tiny_psbnet(), RngKind::Xorshift), cfg);
    let engine = Arc::new(Engine::spawn(factory).unwrap());
    let sup = patient_supervisor(&engine);

    let (out, _) = sup
        .begin_session(plan.clone(), image(0.0), 1, SEED)
        .expect("opening the stream must survive transient faults");
    assert_eq!(out.exec.logits, expect[0].0, "frame 0 logits");
    assert_eq!(out.gated_adds, expect[0].1, "frame 0 billing");
    let mut id = out.session.expect("stream begin keeps a session");
    let _ = engine.pin_session(id, true);

    let mut recovered_frames = 0u64;
    for f in 1..FRAMES {
        let (out, recovered) = sup
            .submit_frame(id, image(f as f32 * 0.1))
            .expect("frame recovery must absorb the schedule within its retry budget");
        let (want_logits, want_adds) = &expect[f as usize];
        assert_eq!(&out.exec.logits, want_logits, "frame {f}: logits drifted under chaos");
        assert_eq!(out.gated_adds, *want_adds, "frame {f}: billing drifted under chaos");
        recovered_frames += recovered as u64;
        if let Some(new_id) = out.session {
            id = new_id;
        }
    }
    let st = sup.stats();
    transcript(
        "resurrected_stream_frames_match_the_oracle",
        &[
            format!("frames={FRAMES} recovered_frames={recovered_frames}"),
            format!(
                "faults_seen={} retries={} resurrections={}",
                stat(&st.faults_seen),
                stat(&st.retries),
                stat(&st.resurrections)
            ),
        ],
    );
    assert!(stat(&st.faults_seen) > 0, "a 30% fault mix must fault somewhere in {FRAMES} frames");
    assert!(
        stat(&st.resurrections) >= 1 && recovered_frames >= 1,
        "some frame must have been served by a resurrected session (faults_seen={})",
        stat(&st.faults_seen)
    );
}

// ----------------------------------------------------- no dropped replies

/// The full coordinator under the complete fault table (slow ops and
/// breaker trips included): every request gets exactly one reply — a
/// bit-valid answer, an explicitly `Degraded` one, or a named error —
/// and `Degraded` never claims it escalated.
#[test]
fn every_request_is_answered_under_chaos() {
    const N: usize = 48;
    let cfg = ChaosConfig {
        seed: chaos_seed().wrapping_add(2),
        transient_permille: 200,
        permanent_permille: 10,
        slow_permille: 20,
        poison_permille: 30,
        geometry_permille: 20,
        slow_op: Duration::from_micros(500),
    };
    let (factory, stats) = chaos_factory(sim_factory(tiny_psbnet(), RngKind::Xorshift), cfg);
    let coord = Coordinator::start_with_factory(
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            batcher: BatcherConfig { batch_size: 4, linger: Duration::from_millis(1), shed_after: None },
            policy: EscalationPolicy { n_low: 4, n_high: 16, ..Default::default() },
            seed: 5,
            pool_cap: 8,
            stream_idle_ttl: Duration::from_secs(30),
            supervisor: SupervisorConfig {
                deadline: Duration::from_secs(5),
                max_retries: 6,
                backoff_base: Duration::from_micros(200),
                breaker_threshold: 4,
                breaker_cooldown: Duration::from_millis(5),
            },
            admission_cap: 256,
            brownout: BrownoutConfig::default(),
            clock: Clock::real(),
        },
        factory,
        IMG,
        NC,
        1_000,
    )
    .unwrap();

    let mut inflight = Vec::with_capacity(N);
    for i in 0..N {
        inflight.push(coord.submit(image(i as f32 * 0.05)).unwrap());
    }
    let mut answered = 0usize;
    let mut degraded = 0usize;
    let mut recovered = 0usize;
    let mut named_errors = 0usize;
    for (i, rx) in inflight.into_iter().enumerate() {
        // recv_timeout: a hang IS the bug this test exists to catch
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("request {i} was dropped or hung under chaos"));
        match reply {
            Ok(resp) => {
                answered += 1;
                assert!(resp.class < NC, "request {i}: class out of range");
                match resp.served {
                    ServedVia::Degraded => {
                        degraded += 1;
                        assert!(!resp.escalated, "request {i}: Degraded must not claim escalation");
                        assert_eq!(resp.n_used, 4, "request {i}: Degraded serves the stage-1 n");
                    }
                    ServedVia::Recovered => recovered += 1,
                    _ => {}
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(!msg.is_empty() && msg.contains("failed"), "unnamed error: {msg}");
                named_errors += 1;
            }
        }
    }

    // streams ride the same contract: a frame on a chaotic stream either
    // answers or errs by name — it never wedges the registry
    let mut frame_ok = 0usize;
    let mut frame_err = 0usize;
    for s in 0..3u64 {
        for f in 0..5u64 {
            match coord.submit_frame(s, image(s as f32 + f as f32 * 0.2)) {
                Ok(resp) => {
                    assert!(resp.class < NC);
                    frame_ok += 1;
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(!msg.is_empty(), "stream errors must be named");
                    frame_err += 1;
                }
            }
        }
    }

    let st = coord.supervisor.stats();
    transcript(
        "every_request_is_answered_under_chaos",
        &[
            format!(
                "answered={answered} degraded={degraded} recovered={recovered} \
                 named_errors={named_errors}"
            ),
            format!("frame_ok={frame_ok} frame_err={frame_err}"),
            format!(
                "faults_seen={} retries={} resurrections={} breaker_trips={} injected={}",
                stat(&st.faults_seen),
                stat(&st.retries),
                stat(&st.resurrections),
                stat(&st.breaker_trips),
                stats.total_faults()
            ),
            format!("metrics: {}", coord.metrics.summary()),
        ],
    );
    assert_eq!(answered + named_errors, N, "every request must be replied to exactly once");
    assert_eq!(frame_ok + frame_err, 15, "every frame call must resolve");
    assert!(
        stat(&st.faults_seen) > 0 && stats.total_faults() > 0,
        "the schedule must actually have injected faults for this test to mean anything"
    );
}

/// Overload *during* faults: a burst far past the admission cap rides
/// the same seeded fault schedule, with the circuit breaker and the
/// brownout ladder active simultaneously.  Reply conservation must hold
/// exactly: every submit either is refused synchronously with a named
/// `(overloaded)` error, or yields exactly one reply — an answer
/// (possibly `Degraded`) or a named error.  Nothing hangs, nothing is
/// double-counted.
#[test]
fn overload_burst_during_faults_conserves_replies() {
    const N: usize = 96;
    let cfg = ChaosConfig {
        seed: chaos_seed().wrapping_add(3),
        transient_permille: 150,
        permanent_permille: 5,
        slow_permille: 50,
        poison_permille: 20,
        geometry_permille: 15,
        slow_op: Duration::from_micros(500),
    };
    let (factory, stats) = chaos_factory(sim_factory(tiny_psbnet(), RngKind::Xorshift), cfg);
    let coord = Coordinator::start_with_factory(
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            batcher: BatcherConfig { batch_size: 4, linger: Duration::from_millis(1), shed_after: None },
            policy: EscalationPolicy { n_low: 4, n_high: 16, ..Default::default() },
            seed: 5,
            pool_cap: 8,
            stream_idle_ttl: Duration::from_secs(30),
            supervisor: SupervisorConfig {
                deadline: Duration::from_secs(5),
                max_retries: 6,
                backoff_base: Duration::from_micros(200),
                breaker_threshold: 3,
                breaker_cooldown: Duration::from_millis(5),
            },
            // a cap far below the burst size forces queue-full refusals,
            // and an eager ladder makes the brownout react inside the
            // burst window
            admission_cap: 8,
            brownout: BrownoutConfig {
                high_milli: 500,
                low_milli: 250,
                dwell_up: Duration::ZERO,
                dwell_down: Duration::from_millis(5),
                ..Default::default()
            },
            clock: Clock::real(),
        },
        factory,
        IMG,
        NC,
        1_000,
    )
    .unwrap();

    let mut refused = 0usize;
    let mut inflight = Vec::with_capacity(N);
    for i in 0..N {
        match coord.submit(image(i as f32 * 0.07)) {
            Ok(rx) => inflight.push(rx),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    is_overloaded(&msg),
                    "a refused submit must carry the (overloaded) marker: {msg}"
                );
                refused += 1;
            }
        }
    }
    let accepted = inflight.len();
    let mut answered = 0usize;
    let mut degraded = 0usize;
    let mut named_errors = 0usize;
    for (i, rx) in inflight.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("accepted request {i} was dropped or hung under overload"));
        match reply {
            Ok(resp) => {
                answered += 1;
                assert!(resp.class < NC, "request {i}: class out of range");
                if resp.served == ServedVia::Degraded {
                    degraded += 1;
                    assert!(!resp.escalated, "request {i}: Degraded must not claim escalation");
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(!msg.is_empty(), "request {i}: errors must be named");
                named_errors += 1;
            }
        }
    }

    let st = coord.supervisor.stats();
    let steps_up = stat(&coord.overload.stats.steps_up);
    transcript(
        "overload_burst_during_faults_conserves_replies",
        &[
            format!(
                "submitted={N} refused={refused} accepted={accepted} answered={answered} \
                 degraded={degraded} named_errors={named_errors}"
            ),
            format!(
                "brownout_level={:?} steps_up={steps_up} admission_shed={} faults_seen={} \
                 breaker_trips={} injected={}",
                coord.overload.level(),
                stat(&coord.overload.stats.shed),
                stat(&st.faults_seen),
                stat(&st.breaker_trips),
                stats.total_faults()
            ),
            format!("metrics: {}", coord.metrics.summary()),
        ],
    );
    // exact conservation: every submit is accounted for exactly once
    assert_eq!(refused + accepted, N);
    assert_eq!(answered + named_errors, accepted, "every accepted request replies exactly once");
    assert!(answered > 0, "goodput must never reach zero while the engine is healthy");
    assert!(
        refused > 0 || steps_up > 0,
        "a {N}-deep burst into an 8-slot queue must visibly engage the overload layer"
    );
    assert!(
        stat(&st.faults_seen) > 0 && stats.total_faults() > 0,
        "the fault schedule must be active during the burst for this test to mean anything"
    );
}
