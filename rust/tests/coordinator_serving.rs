//! Integration: the adaptive-precision coordinator — routing, batching,
//! escalation and metrics invariants.
//!
//! The `sim_*` tests run everywhere on the simulator engine (true
//! progressive-state reuse); the artifact-backed tests additionally
//! exercise the PJRT path and skip when `make artifacts` hasn't run.

use psb::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, EscalationPolicy};
use psb::data::{Dataset, SynthConfig};
use psb::rng::Xorshift128Plus;
use psb::runtime::{FloatBundle, PsbBundle};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::train::{train, TrainConfig};
use std::sync::atomic::Ordering;

const SERVING_SHAPES: [[usize; 2]; 4] = [[27, 16], [144, 32], [288, 32], [32, 10]];

fn setup() -> Option<(PsbBundle, Dataset)> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    if !std::path::Path::new("artifacts/meta.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    let data = Dataset::synth(&SynthConfig {
        train: 512,
        test: 64,
        size: 32,
        seed: 5,
        ..Default::default()
    });
    let mut rng = Xorshift128Plus::seed_from(5);
    let mut net = psb::models::serving_cnn(&mut rng);
    train(&mut net, &data, &TrainConfig { epochs: 1, ..Default::default() });
    let float = FloatBundle::from_network(&net, &SERVING_SHAPES).unwrap();
    let psb = PsbBundle::from_float(&float, Some(4));
    Some((psb, data))
}

fn config(disabled: bool) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: "artifacts".into(),
        batcher: BatcherConfig { batch_size: 8, linger: std::time::Duration::from_millis(1), shed_after: None },
        policy: EscalationPolicy { n_low: 2, n_high: 4, disabled, ..Default::default() },
        seed: 3,
        pool_cap: 32,
        stream_idle_ttl: std::time::Duration::from_secs(30),
        ..Default::default()
    }
}

#[test]
fn every_request_is_answered_exactly_once() {
    let Some((psb, data)) = setup() else { return };
    let coord = Coordinator::start(config(false), psb).unwrap();
    const N: usize = 40;
    let mut inflight = Vec::new();
    for i in 0..N {
        let (x, _) = data.gather_test(&[i % 64]);
        inflight.push(coord.submit(x.data).unwrap());
    }
    let mut answers = 0;
    for rx in inflight {
        let resp = rx.recv().expect("reply must arrive").expect("request must succeed");
        assert!(resp.class < 10);
        assert!(resp.confidence > 0.0 && resp.confidence <= 1.0);
        assert!(resp.n_used == 2 || resp.n_used == 4);
        assert_eq!(resp.escalated, resp.n_used == 4);
        answers += 1;
    }
    assert_eq!(answers, N);
    assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), N as u64);
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), N as u64);
}

#[test]
fn disabled_policy_never_escalates_and_costs_less() {
    let Some((psb, data)) = setup() else { return };
    let run = |disabled: bool| {
        let coord = Coordinator::start(config(disabled), psb.clone()).unwrap();
        let mut inflight = Vec::new();
        for i in 0..24 {
            let (x, _) = data.gather_test(&[i % 64]);
            inflight.push(coord.submit(x.data).unwrap());
        }
        let mut escalated = 0u32;
        for rx in inflight {
            escalated += rx.recv().unwrap().unwrap().escalated as u32;
        }
        (escalated, coord.metrics.gated_adds.load(Ordering::Relaxed))
    };
    let (esc_flat, adds_flat) = run(true);
    let (esc_adaptive, adds_adaptive) = run(false);
    assert_eq!(esc_flat, 0);
    assert!(esc_adaptive > 0, "adaptive mode should escalate something");
    assert!(adds_adaptive > adds_flat, "{adds_adaptive} vs {adds_flat}");
}

#[test]
fn batcher_reports_occupancy_and_latency() {
    let Some((psb, data)) = setup() else { return };
    let coord = Coordinator::start(config(true), psb).unwrap();
    let mut inflight = Vec::new();
    for i in 0..16 {
        let (x, _) = data.gather_test(&[i % 64]);
        inflight.push(coord.submit(x.data).unwrap());
    }
    for rx in inflight {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.latency > std::time::Duration::ZERO);
    }
    let occ = coord.metrics.batch_occupancy();
    assert!(occ >= 1.0 && occ <= 8.0, "occupancy {occ}");
    assert!(coord.metrics.latency.count() == 16);
    assert!(coord.metrics.latency.quantile(0.5) <= coord.metrics.latency.quantile(0.99));
}

#[test]
fn oversized_image_rejected() {
    let Some((psb, _)) = setup() else { return };
    let coord = Coordinator::start(config(true), psb).unwrap();
    assert!(coord.submit(vec![0.0; 17]).is_err());
}

// ---- simulator-engine tests: no artifacts needed ------------------------

fn sim_setup() -> (PsbNetwork, Dataset) {
    let data = Dataset::synth(&SynthConfig {
        train: 256,
        test: 64,
        size: 32,
        seed: 5,
        ..Default::default()
    });
    let mut rng = Xorshift128Plus::seed_from(5);
    let mut net = psb::models::serving_cnn(&mut rng);
    train(&mut net, &data, &TrainConfig { epochs: 1, ..Default::default() });
    (PsbNetwork::prepare(&net, PsbOptions::default()), data)
}

#[test]
fn sim_coordinator_answers_every_request_once() {
    let (psb, data) = sim_setup();
    let coord = Coordinator::start_sim(config(false), psb).unwrap();
    const N: usize = 24;
    let mut inflight = Vec::new();
    for i in 0..N {
        let (x, _) = data.gather_test(&[i % 64]);
        inflight.push(coord.submit(x.data).unwrap());
    }
    let mut answers = 0;
    for rx in inflight {
        let resp = rx.recv().expect("reply must arrive").expect("request must succeed");
        assert!(resp.class < 10);
        assert!(resp.confidence > 0.0 && resp.confidence <= 1.0);
        assert!(resp.n_used == 2 || resp.n_used == 4);
        assert_eq!(resp.escalated, resp.n_used == 4);
        // progressive refinement: escalations inherit the stage-1 samples
        assert_eq!(resp.n_reused, if resp.escalated { 2 } else { 0 });
        // the served-via tag is consistent: direct answers come from
        // stage 1, escalations from a pooled or merged session
        assert_eq!(resp.escalated, resp.served != psb::coordinator::ServedVia::Stage1);
        answers += 1;
    }
    assert_eq!(answers, N);
    assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), N as u64);
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), N as u64);
    // the engine pool hosted the stage-1 sessions and the metrics saw it
    assert!(
        coord.metrics.pool_peak.load(Ordering::Relaxed) >= 1,
        "pool peak must register resident stage-1 sessions"
    );
    let summary = coord.metrics.summary();
    assert!(summary.contains("pool="), "summary must surface the pool: {summary}");
    assert!(summary.contains("merges="), "summary must surface merges: {summary}");
}

#[test]
fn sim_escalations_reuse_progressive_state() {
    let (psb, data) = sim_setup();
    let coord = Coordinator::start_sim(config(false), psb).unwrap();
    let mut inflight = Vec::new();
    for i in 0..32 {
        let (x, _) = data.gather_test(&[i % 64]);
        inflight.push(coord.submit(x.data).unwrap());
    }
    let mut escalated = 0u32;
    for rx in inflight {
        escalated += rx.recv().unwrap().unwrap().escalated as u32;
    }
    assert!(escalated > 0, "adaptive mode should escalate something");
    let reuse = coord.metrics.reuse_ratio();
    assert!(reuse > 0.0, "escalations must register sample reuse");
    // with n_low=2 / n_high=4 the reuse ratio is bounded by 2/(4+2)
    assert!(reuse <= 2.0 / 6.0 + 1e-9, "reuse {reuse}");
    let paid = coord.metrics.samples_paid.load(Ordering::Relaxed);
    let reused = coord.metrics.samples_reused.load(Ordering::Relaxed);
    assert_eq!(reused, 2 * escalated as u64);
    assert_eq!(paid, 2 * 32 + 2 * escalated as u64);
}

#[test]
fn sim_flat_serving_never_escalates_and_costs_less() {
    let (psb, data) = sim_setup();
    let run = |disabled: bool| {
        let coord = Coordinator::start_sim(config(disabled), psb.clone()).unwrap();
        let mut inflight = Vec::new();
        for i in 0..16 {
            let (x, _) = data.gather_test(&[i % 64]);
            inflight.push(coord.submit(x.data).unwrap());
        }
        let mut escalated = 0u32;
        for rx in inflight {
            escalated += rx.recv().unwrap().unwrap().escalated as u32;
        }
        (escalated, coord.metrics.gated_adds.load(Ordering::Relaxed))
    };
    let (esc_flat, adds_flat) = run(true);
    let (esc_adaptive, adds_adaptive) = run(false);
    assert_eq!(esc_flat, 0);
    assert!(esc_adaptive > 0, "adaptive mode should escalate something");
    assert!(adds_adaptive > adds_flat, "{adds_adaptive} vs {adds_flat}");
}

// ---- streaming: temporal frame traffic over pinned sessions -------------

#[test]
fn sim_streams_serve_frames_via_rebase() {
    let (psb, data) = sim_setup();
    let coord = Coordinator::start_sim(config(false), psb).unwrap();
    let (x0, _) = data.gather_test(&[0]);
    let (x1, _) = data.gather_test(&[1]);
    // frame 1 opens the stream (fresh pass, session pinned); frames 2-3
    // rebase that session onto the drifting input
    let r0 = coord.submit_frame(7, x0.data.clone()).unwrap();
    assert_eq!(r0.served, psb::coordinator::ServedVia::Stream);
    assert!(r0.class < 10 && r0.confidence > 0.0 && r0.confidence <= 1.0);
    let mut drift = x0.data.clone();
    drift[..2 * 32 * 3].copy_from_slice(&x1.data[..2 * 32 * 3]); // top 2 pixel rows move
    let r1 = coord.submit_frame(7, drift).unwrap();
    assert_eq!(r1.served, psb::coordinator::ServedVia::Stream);
    assert!(r1.n_used == 2 || r1.n_used == 4);
    assert_eq!(r1.n_reused, if r1.escalated { 2 } else { 0 });
    let r2 = coord.submit_frame(7, x1.data.clone()).unwrap();
    assert_eq!(r2.served, psb::coordinator::ServedVia::Stream);
    // the stream counters flowed into the serving metrics and summary
    assert_eq!(coord.metrics.stream_frames.load(Ordering::Relaxed), 2, "two rebased frames");
    assert!(
        coord.metrics.stream_rows_reused.load(Ordering::Relaxed) > 0,
        "the mostly-unchanged frame must register reuse"
    );
    let mf = coord.metrics.stream_mean_frac();
    assert!(mf > 0.0 && mf <= 1.0, "mean rebase fraction {mf}");
    let summary = coord.metrics.summary();
    assert!(summary.contains("stream="), "summary must surface streaming: {summary}");
    // ordinary classify traffic keeps flowing next to the stream
    let resp = coord.classify(x0.data).unwrap();
    assert!(resp.class < 10);
    coord.close_stream(7).unwrap();
}

#[test]
fn int_streams_serve_frames_on_the_integer_backend() {
    let (psb, data) = sim_setup();
    let coord = Coordinator::start_int(config(false), psb).unwrap();
    let (x0, _) = data.gather_test(&[2]);
    let (x1, _) = data.gather_test(&[3]);
    let r0 = coord.submit_frame(1, x0.data.clone()).unwrap();
    let mut drift = x0.data;
    drift[..2 * 32 * 3].copy_from_slice(&x1.data[..2 * 32 * 3]);
    let r1 = coord.submit_frame(1, drift).unwrap();
    for r in [&r0, &r1] {
        assert_eq!(r.served, psb::coordinator::ServedVia::Stream);
        assert!(r.class < 10 && r.confidence > 0.0);
    }
    assert_eq!(coord.metrics.stream_frames.load(Ordering::Relaxed), 1);
    // the O(Δ) path reports real executed work through the metrics
    assert!(coord.metrics.executed_adds.load(Ordering::Relaxed) > 0);
}

// ---- integer-engine tests: serving on the IntKernel backend -------------

#[test]
fn int_coordinator_answers_every_request_once() {
    let (psb, data) = sim_setup();
    let coord = Coordinator::start_int(config(false), psb).unwrap();
    const N: usize = 24;
    let mut inflight = Vec::new();
    for i in 0..N {
        let (x, _) = data.gather_test(&[i % 64]);
        inflight.push(coord.submit(x.data).unwrap());
    }
    let mut answers = 0;
    for rx in inflight {
        let resp = rx.recv().expect("reply must arrive").expect("request must succeed");
        assert!(resp.class < 10);
        assert!(resp.confidence > 0.0 && resp.confidence <= 1.0);
        assert!(resp.n_used == 2 || resp.n_used == 4);
        assert_eq!(resp.escalated, resp.n_used == 4);
        assert_eq!(resp.n_reused, if resp.escalated { 2 } else { 0 });
        assert_eq!(resp.escalated, resp.served != psb::coordinator::ServedVia::Stage1);
        answers += 1;
    }
    assert_eq!(answers, N);
    assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), N as u64);
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), N as u64);
    // the integer backend reports real executed work to the metrics
    assert!(coord.metrics.executed_adds.load(Ordering::Relaxed) > 0);
}

/// The engine's stage-2 shape — narrow an open session to the uncertain
/// rows, refine to a *spatial* plan — runs on IntKernel sessions: the
/// row-masked contraction accepts the masked target and reports both
/// executed and charged work.
#[test]
fn int_engine_accepts_masked_narrow_refine() {
    let (psb, data) = sim_setup();
    let (h, w, _c) = psb.input_hwc;
    let engine = psb::coordinator::Engine::spawn(psb::backend::int_kernel_factory(
        psb,
        psb::rng::RngKind::Philox,
    ))
    .unwrap();
    let (x, _) = data.gather_test(&[0, 1, 2, 3]);
    let out = engine
        .begin_session(psb::precision::PrecisionPlan::uniform(4), x.data, 4, 7)
        .unwrap();
    let sid = out.session.expect("keep-session begin returns an id");
    let rows = vec![1usize, 3];
    // attend to the top half of each narrowed image
    let mask: Vec<bool> = (0..rows.len() * h * w).map(|i| (i % (h * w)) / w < h / 2).collect();
    let refined = engine
        .refine_session(sid, Some(rows), psb::precision::PrecisionPlan::spatial(mask, 4, 8))
        .unwrap();
    assert_eq!(refined.exec.logits.len(), 2 * 10, "two narrowed rows × 10 classes");
    assert!(refined.executed_adds > 0, "masked refine must report executed work");
    assert!(refined.gated_adds > 0, "masked refine must charge the attended rows");
    assert!(
        refined.gated_adds < out.gated_adds,
        "half-mask Δ4 increment must charge less than the full stage-1 pass"
    );
}
