//! Regression tests for the unified precision API: deterministic
//! seeding, the refine-vs-direct additivity invariant (Eq. 8–10), plan
//! saturation semantics, and the budgeted policy's cost guarantees.

use psb::precision::{
    Budgeted, PlanContext, PlanError, PrecisionPlan, PrecisionPolicy, SpatialAttention,
};
use psb::rng::{Rng, RngKind, Xorshift128Plus};
use psb::sim::network::{Network, Op};
use psb::sim::psbnet::{PsbNetwork, PsbOptions, PsbOutput};
use psb::sim::tensor::Tensor;

const KINDS: [RngKind; 3] = [RngKind::Xorshift, RngKind::Lfsr, RngKind::Philox];

/// One-shot pass: begin + refine (what the backends' `begin` does).
fn fwd_kind(
    psb: &PsbNetwork,
    x: &Tensor,
    plan: &PrecisionPlan,
    kind: RngKind,
    seed: u64,
) -> Result<PsbOutput, PlanError> {
    let mut st = psb.begin(kind, seed);
    psb.refine(x, &mut st, plan)
}

fn fwd(
    psb: &PsbNetwork,
    x: &Tensor,
    plan: &PrecisionPlan,
    seed: u64,
) -> Result<PsbOutput, PlanError> {
    fwd_kind(psb, x, plan, RngKind::Xorshift, seed)
}

/// Small conv net; `with_residual_bn` adds an unfoldable BN so the
/// stochastic-channel-scale unit participates in the invariants.
fn make_net(with_residual_bn: bool) -> Network {
    let mut net = Network::new((8, 8, 3), "progressive-test");
    let c1 = net.add(Op::Conv { k: 3, stride: 2, cin: 3, cout: 8 }, vec![0], "c1");
    let b1 = net.add(Op::BatchNorm, vec![c1], "bn1");
    let r1 = net.add(Op::ReLU, vec![b1], "r1");
    let c2 = net.add(Op::Conv { k: 3, stride: 1, cin: 8, cout: 8 }, vec![r1], "c2");
    let tail = if with_residual_bn {
        let a = net.add(Op::Add, vec![c2, r1], "add");
        let b2 = net.add(Op::BatchNorm, vec![a], "bn2");
        net.add(Op::ReLU, vec![b2], "r2")
    } else {
        let b2 = net.add(Op::BatchNorm, vec![c2], "bn2");
        let a = net.add(Op::Add, vec![b2, r1], "add");
        net.add(Op::ReLU, vec![a], "r2")
    };
    net.feat_node = Some(tail);
    let g = net.add(Op::GlobalAvgPool, vec![tail], "gap");
    net.add(Op::Dense { cin: 8, cout: 4 }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(21);
    net.init(&mut rng);
    net
}

fn prepared(with_residual_bn: bool, options: PsbOptions) -> PsbNetwork {
    let mut net = make_net(with_residual_bn);
    for s in 0..8 {
        let x = batch(s, 4);
        net.forward::<Xorshift128Plus>(&x, true, None);
    }
    PsbNetwork::prepare(&net, options)
}

fn batch(seed: u64, b: usize) -> Tensor {
    let mut rng = Xorshift128Plus::seed_from(seed);
    Tensor::from_vec((0..b * 8 * 8 * 3).map(|_| rng.uniform()).collect(), &[b, 8, 8, 3])
}

#[test]
fn same_seed_same_plan_is_bit_identical_for_every_rng() {
    let psb = prepared(true, PsbOptions::default());
    let x = batch(3, 2);
    let plan = PrecisionPlan::per_layer(&[4, 8, 16]).unwrap();
    for kind in KINDS {
        let a = fwd_kind(&psb, &x, &plan, kind, 99).unwrap();
        let b = fwd_kind(&psb, &x, &plan, kind, 99).unwrap();
        assert_eq!(a.logits.data, b.logits.data, "{kind:?}: same seed must reproduce");
        let c = fwd_kind(&psb, &x, &plan, kind, 100).unwrap();
        assert_ne!(a.logits.data, c.logits.data, "{kind:?}: different seed must differ");
    }
}

#[test]
fn refine_equals_direct_pass_for_every_rng() {
    // the unbiasedness/additivity invariant: n_low → n_high refinement
    // is bit-identical to a one-shot n_high pass (Eq. 8)
    let psb = prepared(true, PsbOptions::default());
    let x = batch(7, 2);
    for kind in KINDS {
        let direct = fwd_kind(&psb, &x, &PrecisionPlan::uniform(16), kind, 5).unwrap();
        let mut st = psb.begin(kind, 5);
        let stage1 = psb.refine(&x, &mut st, &PrecisionPlan::uniform(4)).unwrap();
        let mid = psb.refine(&x, &mut st, &PrecisionPlan::uniform(9)).unwrap();
        let fin = psb.refine(&x, &mut st, &PrecisionPlan::uniform(16)).unwrap();
        assert_eq!(fin.logits.data, direct.logits.data, "{kind:?}: 4→9→16 != direct 16");
        // progressive accounting: the stages partition the direct cost
        assert_eq!(
            stage1.costs.gated_adds + mid.costs.gated_adds + fin.costs.gated_adds,
            direct.costs.gated_adds,
            "{kind:?}"
        );
        assert!(fin.costs.gated_adds < direct.costs.gated_adds);
    }
}

#[test]
fn spatial_refine_equals_direct_spatial_pass() {
    let psb = prepared(false, PsbOptions::default());
    let x = batch(11, 2);
    // top half of each image attended (block mask survives OR-pooling)
    let mask: Vec<bool> = (0..2 * 8 * 8).map(|i| (i % 64) < 32).collect();
    let plan = PrecisionPlan::spatial(mask, 6, 14);
    let direct = fwd(&psb, &x, &plan, 31).unwrap();
    let mut st = psb.begin(RngKind::Xorshift, 31);
    psb.refine(&x, &mut st, &PrecisionPlan::uniform(6)).unwrap();
    let refined = psb.refine(&x, &mut st, &plan).unwrap();
    assert_eq!(refined.logits.data, direct.logits.data);
}

#[test]
fn exact_integer_refine_is_bit_identical() {
    let psb = prepared(false, PsbOptions { exact_integer: true, ..Default::default() });
    let x = batch(13, 1);
    let direct = fwd(&psb, &x, &PrecisionPlan::uniform(16), 2).unwrap();
    let mut st = psb.begin(RngKind::Xorshift, 2);
    psb.refine(&x, &mut st, &PrecisionPlan::uniform(8)).unwrap();
    let refined = psb.refine(&x, &mut st, &PrecisionPlan::uniform(16)).unwrap();
    assert_eq!(refined.logits.data, direct.logits.data, "integer datapath must refine exactly");
}

#[test]
fn short_plans_saturate_and_empty_plans_error() {
    let psb = prepared(false, PsbOptions::default());
    assert_eq!(psb.num_capacitors, 3);
    let x = batch(17, 2);
    let short = PrecisionPlan::per_layer(&[4, 8]).unwrap();
    let padded = PrecisionPlan::per_layer(&[4, 8, 8]).unwrap();
    let a = fwd(&psb, &x, &short, 23).unwrap();
    let b = fwd(&psb, &x, &padded, 23).unwrap();
    assert_eq!(a.logits.data, b.logits.data, "saturation == explicit padding");
    assert_eq!(PrecisionPlan::per_layer(&[]).unwrap_err(), PlanError::Empty);
    assert!(matches!(
        fwd(&psb, &x, &PrecisionPlan::uniform(0), 1).unwrap_err(),
        PlanError::ZeroSamples { .. }
    ));
}

#[test]
fn budgeted_policy_water_fills_within_budget_exactly() {
    let psb = prepared(false, PsbOptions::default());
    let ctx = PlanContext::for_network(&psb, 2);
    let per_sample = ctx.total_macs_per_sample();
    assert!(per_sample > 0);
    assert_eq!(ctx.layer_var.len(), ctx.layer_macs.len(), "for_network fills variances");
    let mut prev_cost = u64::MAX;
    for budget in [200 * per_sample, 33 * per_sample, 9 * per_sample, 3 * per_sample + 1] {
        let plan = Budgeted { gated_add_budget: budget, n_max: 128 }.plan(&ctx).unwrap();
        let estimate = plan.estimate_cost(&ctx.layer_macs);
        assert!(
            estimate.gated_adds <= budget,
            "estimate {} exceeds budget {budget}",
            estimate.gated_adds
        );
        // the estimate is exact for per-layer plans: the actual forward
        // charges the same gated adds
        let x = batch(29, 2);
        let out = fwd(&psb, &x, &plan, 4).unwrap();
        assert_eq!(out.costs.gated_adds, estimate.gated_adds);
        assert!(out.costs.gated_adds <= budget);
        assert!(
            estimate.gated_adds <= prev_cost,
            "tighter budget must not raise spend: {} > {prev_cost}",
            estimate.gated_adds
        );
        prev_cost = estimate.gated_adds;
    }
    assert!(matches!(
        Budgeted { gated_add_budget: per_sample - 1, n_max: 128 }.plan(&ctx),
        Err(PlanError::BudgetTooTight { .. })
    ));
}

#[test]
fn spatial_attention_policy_builds_plans_from_features() {
    let psb = prepared(false, PsbOptions::default());
    let x = batch(37, 2);
    let stage1 = fwd(&psb, &x, &PrecisionPlan::uniform(8), 6).unwrap();
    let feat = stage1.feat.as_ref().expect("feat node designated");
    let plan = SpatialAttention {
        n_low: 8,
        n_high: 16,
        threshold: psb::attention::Threshold::Mean,
    }
    .plan(&PlanContext::for_network(&psb, 2).with_feat(feat))
    .unwrap();
    let f = plan.mask_fraction();
    assert!(f > 0.0 && f < 1.0, "mean threshold splits the image: {f}");
    assert_eq!(plan.mask().unwrap().len(), 2 * 8 * 8, "mask at input resolution");
    // the plan refines the stage-1 state monotonically
    let mut st = psb.begin(RngKind::Xorshift, 6);
    psb.refine(&x, &mut st, &PrecisionPlan::uniform(8)).unwrap();
    psb.refine(&x, &mut st, &plan).unwrap();
}
