//! The pooled engine: several stage-1 sessions resident at once,
//! stage-2 narrow+refine resolving against the *correct* pooled session
//! by id, LRU eviction and close with precise error reporting, and
//! merged dispatch of compatible escalation groups — including the
//! PJRT-shaped (stateless) merge, where two escalation groups coalesce
//! into **one** backend execution.
//!
//! The stateless backend here is a mock with PJRT's exact session
//! shape: no capacitor state, `refine` re-executes a pure function of
//! `(rows, seed, n)`, and `merge_sessions` fuses parts into one run —
//! so coalescing is observable as a single execution-counter increment
//! while per-part outputs stay bit-identical to serial re-execution.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use psb::backend::{
    Backend, CostReport, InferenceSession, MergeOutcome, SimBackend, StepReport,
};
use psb::coordinator::{Engine, EngineConfig, EngineJob};
use psb::precision::PrecisionPlan;
use psb::rng::Xorshift128Plus;
use psb::sim::network::{Network, Op};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::tensor::Tensor;

// ---- a PJRT-shaped stateless mock backend -------------------------------

const H: usize = 2;
const W: usize = 2;
const C: usize = 1;
const NC: usize = 2;
const IMG: usize = H * W * C;

/// The mock's "model": a pure function of one row, its batch seed and
/// the sample size — exactly the identity a stateless re-execution must
/// preserve (and the oracle the tests compare merged outputs against).
fn mock_logit(row: &[f32], seed: u64, n: u32) -> [f32; NC] {
    let s: f32 = row.iter().sum();
    [s * n as f32 + seed as f32, s - seed as f32]
}

#[derive(Clone)]
struct MockStateless {
    /// Backend executions performed ("artifact runs").
    runs: Arc<AtomicU64>,
    /// Milliseconds each `begin` sleeps — lets a test hold the engine
    /// busy so follow-up jobs pile into one dispatch window.
    begin_delay_ms: Arc<AtomicU64>,
}

fn mock_backend() -> MockStateless {
    MockStateless {
        runs: Arc::new(AtomicU64::new(0)),
        begin_delay_ms: Arc::new(AtomicU64::new(0)),
    }
}

struct MockSession {
    runs: Arc<AtomicU64>,
    begin_delay_ms: Arc<AtomicU64>,
    plan: PrecisionPlan,
    x: Vec<f32>,
    rows: usize,
    seed: u64,
    logits: Tensor,
    report: CostReport,
}

impl MockSession {
    fn execute(&mut self, n: u32) {
        self.runs.fetch_add(1, Ordering::SeqCst);
        let mut data = Vec::with_capacity(self.rows * NC);
        for r in 0..self.rows {
            data.extend_from_slice(&mock_logit(&self.x[r * IMG..(r + 1) * IMG], self.seed, n));
        }
        self.logits = Tensor::from_vec(data, &[self.rows, NC]);
        self.report.record(StepReport::default());
    }
}

impl InferenceSession for MockSession {
    fn begin(&mut self, x: &Tensor, seed: u64) -> Result<StepReport> {
        let delay = self.begin_delay_ms.load(Ordering::SeqCst);
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_millis(delay));
        }
        self.x = x.data.clone();
        self.rows = x.shape[0];
        self.seed = seed;
        let n = self.plan.uniform_n().ok_or_else(|| anyhow!("mock is uniform-only"))?;
        self.execute(n);
        Ok(StepReport::default())
    }

    fn refine(&mut self, target: &PrecisionPlan) -> Result<StepReport> {
        let n = target.uniform_n().ok_or_else(|| anyhow!("mock is uniform-only"))?;
        self.execute(n);
        self.plan = target.clone();
        Ok(StepReport::default())
    }

    fn narrow(&mut self, rows: &[usize]) -> Result<()> {
        let mut nx = Vec::with_capacity(rows.len() * IMG);
        let mut nl = Vec::with_capacity(rows.len() * NC);
        for &r in rows {
            anyhow::ensure!(r < self.rows, "row {r} out of range");
            nx.extend_from_slice(&self.x[r * IMG..(r + 1) * IMG]);
            nl.extend_from_slice(&self.logits.data[r * NC..(r + 1) * NC]);
        }
        self.x = nx;
        self.rows = rows.len();
        self.logits = Tensor::from_vec(nl, &[self.rows, NC]);
        Ok(())
    }

    fn logits(&self) -> &Tensor {
        &self.logits
    }

    fn feat(&self) -> Option<&Tensor> {
        None
    }

    fn plan(&self) -> &PrecisionPlan {
        &self.plan
    }

    fn cost_report(&self) -> &CostReport {
        &self.report
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Two-plus stateless sessions fused: one execution covers every part's
/// rows, each under its *own* seed identity.
struct MockFused {
    runs: Arc<AtomicU64>,
    /// `(rows, seed, x)` per part, in order.
    parts: Vec<(usize, u64, Vec<f32>)>,
    plan: PrecisionPlan,
    logits: Tensor,
    report: CostReport,
}

impl InferenceSession for MockFused {
    fn begin(&mut self, _x: &Tensor, _seed: u64) -> Result<StepReport> {
        Err(anyhow!("fused sessions are merged from begun sessions"))
    }

    fn refine(&mut self, target: &PrecisionPlan) -> Result<StepReport> {
        let n = target.uniform_n().ok_or_else(|| anyhow!("mock is uniform-only"))?;
        // the whole point: ONE backend execution for every part
        self.runs.fetch_add(1, Ordering::SeqCst);
        let mut data = Vec::new();
        let mut rows = 0usize;
        for (prows, seed, x) in &self.parts {
            for r in 0..*prows {
                data.extend_from_slice(&mock_logit(&x[r * IMG..(r + 1) * IMG], *seed, n));
            }
            rows += prows;
        }
        self.logits = Tensor::from_vec(data, &[rows, NC]);
        self.plan = target.clone();
        let step = StepReport::default();
        self.report.record(step.clone());
        Ok(step)
    }

    fn narrow(&mut self, _rows: &[usize]) -> Result<()> {
        Err(anyhow!("merged mock sessions are narrowed before the merge"))
    }

    fn logits(&self) -> &Tensor {
        &self.logits
    }

    fn feat(&self) -> Option<&Tensor> {
        None
    }

    fn plan(&self) -> &PrecisionPlan {
        &self.plan
    }

    fn cost_report(&self) -> &CostReport {
        &self.report
    }

    fn part_rows(&self) -> Vec<usize> {
        self.parts.iter().map(|(r, _, _)| *r).collect()
    }

    fn part_steps(&self) -> Vec<StepReport> {
        self.parts.iter().map(|_| StepReport::default()).collect()
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl Backend for MockStateless {
    fn name(&self) -> &'static str {
        "mock-stateless"
    }

    fn input_hwc(&self) -> (usize, usize, usize) {
        (H, W, C)
    }

    fn open(&self, plan: &PrecisionPlan) -> Result<Box<dyn InferenceSession>> {
        Ok(Box::new(MockSession {
            runs: self.runs.clone(),
            begin_delay_ms: self.begin_delay_ms.clone(),
            plan: plan.clone(),
            x: Vec::new(),
            rows: 0,
            seed: 0,
            logits: Tensor::zeros(&[0]),
            report: CostReport::default(),
        }))
    }

    fn merge_sessions(&self, sessions: Vec<Box<dyn InferenceSession>>) -> Result<MergeOutcome> {
        if sessions.len() < 2
            || !sessions.iter().all(|s| s.as_any().downcast_ref::<MockSession>().is_some())
        {
            return Ok(MergeOutcome::Unsupported(sessions));
        }
        let mut parts = Vec::with_capacity(sessions.len());
        let mut data = Vec::new();
        let mut rows = 0usize;
        for s in &sessions {
            let p = s.as_any().downcast_ref::<MockSession>().expect("checked");
            parts.push((p.rows, p.seed, p.x.clone()));
            data.extend_from_slice(&p.logits.data);
            rows += p.rows;
        }
        Ok(MergeOutcome::Merged(Box::new(MockFused {
            runs: self.runs.clone(),
            parts,
            plan: sessions[0].plan().clone(),
            logits: Tensor::from_vec(data, &[rows, NC]),
            report: CostReport::default(),
        })))
    }
}

fn mock_factory(mock: &MockStateless) -> psb::backend::BackendFactory {
    let m = mock.clone();
    Box::new(move || Ok(Box::new(m) as Box<dyn Backend>))
}

fn image(tag: f32, rows: usize) -> Vec<f32> {
    (0..rows * IMG).map(|i| tag + i as f32 * 0.25).collect()
}

fn expect_logits(x: &[f32], rows: &[usize], seed: u64, n: u32) -> Vec<f32> {
    let mut out = Vec::new();
    for &r in rows {
        out.extend_from_slice(&mock_logit(&x[r * IMG..(r + 1) * IMG], seed, n));
    }
    out
}

// ---- pool residency + correct per-session resolution --------------------

#[test]
fn pool_keeps_sessions_resident_and_stage2_resolves_the_right_one() {
    let mock = mock_backend();
    let engine = Engine::spawn(mock_factory(&mock)).unwrap();
    let plan8 = PrecisionPlan::uniform(8);
    let (xa, xb, xc) = (image(1.0, 3), image(100.0, 3), image(10_000.0, 3));
    let a = engine.begin_session(plan8.clone(), xa.clone(), 3, 11).unwrap();
    let b = engine.begin_session(plan8.clone(), xb.clone(), 3, 22).unwrap();
    let c = engine.begin_session(plan8, xc, 3, 33).unwrap();
    assert_eq!(
        engine.stats().sessions_open(),
        3,
        "three stage-1 sessions must be concurrently resident"
    );
    // stage-2 shape: narrow the *middle* session to its uncertain rows
    // and refine — the answer must come from b's state, not a's or c's
    let out = engine
        .refine_session(b.session.unwrap(), Some(vec![0, 2]), PrecisionPlan::uniform(16))
        .unwrap();
    assert_eq!(out.exec.logits, expect_logits(&xb, &[0, 2], 22, 16));
    assert_eq!(engine.stats().sessions_open(), 2, "the refined session closed");
    // a duplicate/late refine of the consumed session names what
    // happened to it, not "unknown session"
    let err = engine
        .refine_session(b.session.unwrap(), None, PrecisionPlan::uniform(16))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("completed refine"),
        "consumed sessions must be retired with a reason: {msg}"
    );
    // the others still resolve correctly afterwards
    let out_a = engine
        .refine_session(a.session.unwrap(), None, PrecisionPlan::uniform(16))
        .unwrap();
    assert_eq!(out_a.exec.logits, expect_logits(&xa, &[0, 1, 2], 11, 16));
    let _ = c;
}

#[test]
fn sim_pool_narrow_refine_is_bit_identical_to_a_dedicated_engine() {
    let psb = tiny_psbnet();
    let engine =
        Engine::spawn(psb::backend::sim_factory(psb.clone(), psb::rng::RngKind::Philox)).unwrap();
    let (h, w, c) = psb.input_hwc;
    let img = h * w * c;
    let mk_x = |tag: f32, rows: usize| -> Vec<f32> {
        (0..rows * img).map(|i| (tag + i as f32 * 0.37).sin().abs()).collect()
    };
    let (xa, xb) = (mk_x(0.3, 4), mk_x(5.0, 4));
    let a = engine.begin_session(PrecisionPlan::uniform(4), xa.clone(), 4, 7).unwrap();
    let b = engine.begin_session(PrecisionPlan::uniform(4), xb.clone(), 4, 9).unwrap();
    assert!(engine.stats().sessions_open() >= 2, "two sim sessions resident");
    let got_b = engine
        .refine_session(b.session.unwrap(), Some(vec![1, 3]), PrecisionPlan::uniform(8))
        .unwrap();
    let got_a = engine
        .refine_session(a.session.unwrap(), Some(vec![0, 2]), PrecisionPlan::uniform(8))
        .unwrap();
    // oracle: a dedicated single-session backend run, same (x, seed)
    let oracle = |x: &Vec<f32>, seed: u64, rows: Vec<usize>| -> Vec<f32> {
        let backend = SimBackend::new(psb.clone());
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        sess.begin(&Tensor::from_vec(x.clone(), &[4, h, w, c]), seed).unwrap();
        sess.narrow(&rows).unwrap();
        sess.refine(&PrecisionPlan::uniform(8)).unwrap();
        sess.logits().data.clone()
    };
    assert_eq!(got_b.exec.logits, oracle(&xb, 9, vec![1, 3]), "pooled b ≡ serial b");
    assert_eq!(got_a.exec.logits, oracle(&xa, 7, vec![0, 2]), "pooled a ≡ serial a");
}

// ---- stateless merge: two escalation groups, one dispatch ---------------

#[test]
fn stateless_merge_coalesces_two_escalation_groups_into_one_run() {
    let mock = mock_backend();
    let engine = Engine::spawn(mock_factory(&mock)).unwrap();
    let plan8 = PrecisionPlan::uniform(8);
    let (xa, xb) = (image(1.0, 4), image(50.0, 4));
    // two stage-1 "batches" → two pooled sessions → two escalation groups
    let a = engine.begin_session(plan8.clone(), xa.clone(), 4, 5).unwrap();
    let b = engine.begin_session(plan8.clone(), xb.clone(), 4, 6).unwrap();
    // hold the engine busy so both refines land in one dispatch window
    mock.begin_delay_ms.store(80, Ordering::SeqCst);
    let (blk_reply, blk_rx) = mpsc::sync_channel(1);
    engine
        .submit(EngineJob::Begin {
            plan: plan8,
            x: image(0.0, 1),
            batch: 1,
            seed: 0,
            keep: false,
            reply: blk_reply,
        })
        .unwrap();
    let runs_before = mock.runs.load(Ordering::SeqCst);
    let plan16 = PrecisionPlan::uniform(16);
    let (reply_a, rx_a) = mpsc::sync_channel(1);
    engine
        .submit(EngineJob::Refine {
            session: a.session.unwrap(),
            rows: Some(vec![0, 2]),
            plan: plan16.clone(),
            keep: false,
            reply: reply_a,
        })
        .unwrap();
    let (reply_b, rx_b) = mpsc::sync_channel(1);
    engine
        .submit(EngineJob::Refine {
            session: b.session.unwrap(),
            rows: Some(vec![1, 2, 3]),
            plan: plan16,
            keep: false,
            reply: reply_b,
        })
        .unwrap();
    let blocker = blk_rx.recv().unwrap();
    mock.begin_delay_ms.store(0, Ordering::SeqCst);
    let out_a = rx_a.recv().unwrap().unwrap();
    let out_b = rx_b.recv().unwrap().unwrap();
    assert!(blocker.is_ok());
    // one merged dispatch = exactly one backend execution for both
    // groups (the blocker begin was the only other run)
    let runs_after = mock.runs.load(Ordering::SeqCst);
    assert_eq!(
        runs_after - runs_before,
        2,
        "blocker begin (1) + merged escalation (1); serial dispatch would be 3"
    );
    assert!(out_a.merged && out_b.merged, "both outputs must be flagged merged");
    assert_eq!(engine.stats().merges.load(Ordering::SeqCst), 1);
    assert_eq!(engine.stats().runs_saved.load(Ordering::SeqCst), 1);
    // bit-identity per group: each part kept its own seed identity
    assert_eq!(out_a.exec.logits, expect_logits(&xa, &[0, 2], 5, 16));
    assert_eq!(out_b.exec.logits, expect_logits(&xb, &[1, 2, 3], 6, 16));
}

// ---- error paths under pooling ------------------------------------------

#[test]
fn closed_session_ids_are_retired_never_reused() {
    let mock = mock_backend();
    let engine = Engine::spawn(mock_factory(&mock)).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let a = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap();
    let id_a = a.session.unwrap();
    engine.close_session(id_a).unwrap();
    let err = engine
        .refine_session(id_a, None, PrecisionPlan::uniform(16))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("was closed"), "refine-after-close must name the close: {msg}");
    // ids are monotonic: a new session never reuses the closed id
    let b = engine.begin_session(plan, image(2.0, 2), 2, 2).unwrap();
    assert!(b.session.unwrap() > id_a, "session ids must never be reused");
}

#[test]
fn evicted_sessions_name_the_eviction_in_last_error() {
    let mock = mock_backend();
    let engine =
        Engine::spawn_with(mock_factory(&mock), EngineConfig { pool_cap: 2, ..Default::default() }).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let a = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap();
    let b = engine.begin_session(plan.clone(), image(2.0, 2), 2, 2).unwrap();
    let c = engine.begin_session(plan, image(3.0, 2), 2, 3).unwrap();
    assert_eq!(engine.stats().sessions_open(), 2, "pool bounded at capacity");
    assert_eq!(engine.stats().evictions.load(Ordering::SeqCst), 1);
    // the LRU session (a) was evicted; refining it names the eviction
    let err = engine
        .refine_session(a.session.unwrap(), None, PrecisionPlan::uniform(16))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("evicted") && msg.contains("capacity 2"),
        "eviction must be named with the pool bound: {msg}"
    );
    let last = engine.last_error().expect("eviction refine failure is recorded");
    assert!(last.contains("evicted"), "Engine::last_error must name the eviction: {last}");
    // the resident sessions still refine fine
    assert!(engine.refine_session(b.session.unwrap(), None, PrecisionPlan::uniform(16)).is_ok());
    assert!(engine.refine_session(c.session.unwrap(), None, PrecisionPlan::uniform(16)).is_ok());
}

#[test]
fn close_while_queued_does_not_wedge_the_job_loop() {
    let mock = mock_backend();
    let engine = Engine::spawn(mock_factory(&mock)).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let a = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap();
    let id = a.session.unwrap();
    // refine + close queued back-to-back: the refine (queued first)
    // wins, the close is an idempotent no-op afterwards
    let (reply, rx) = mpsc::sync_channel(1);
    engine
        .submit(EngineJob::Refine {
            session: id,
            rows: None,
            plan: PrecisionPlan::uniform(16),
            keep: false,
            reply,
        })
        .unwrap();
    engine.close_session(id).unwrap();
    assert!(rx.recv().unwrap().is_ok(), "queued refine must still be served");
    // closing garbage ids must not wedge anything either
    engine.close_session(9999).unwrap();
    // the loop is alive and serving
    let ok = engine.run_once(plan, image(4.0, 2), 2, 9).unwrap();
    assert_eq!(ok.exec.logits.len(), 2 * NC);
}

// ---- pinned sessions + streaming frames ---------------------------------

#[test]
fn pinned_sessions_survive_pool_pressure() {
    let mock = mock_backend();
    let engine =
        Engine::spawn_with(mock_factory(&mock), EngineConfig { pool_cap: 2, ..Default::default() }).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let xa = image(1.0, 2);
    let a = engine.begin_session(plan.clone(), xa.clone(), 2, 1).unwrap().session.unwrap();
    engine.pin_session(a, true).unwrap();
    // pressure: three more sessions through a cap-2 pool
    let b = engine.begin_session(plan.clone(), image(2.0, 2), 2, 2).unwrap().session.unwrap();
    let c = engine.begin_session(plan.clone(), image(3.0, 2), 2, 3).unwrap().session.unwrap();
    let _d = engine.begin_session(plan.clone(), image(4.0, 2), 2, 4).unwrap().session.unwrap();
    assert_eq!(engine.stats().sessions_open(), 2, "pool still bounded at capacity");
    // the unpinned LRU sessions were evicted around the pinned one
    let msg = format!(
        "{:#}",
        engine.refine_session(b, None, PrecisionPlan::uniform(16)).unwrap_err()
    );
    assert!(msg.contains("evicted"), "unpinned b must have been evicted: {msg}");
    let _ = c;
    // the pinned session outlived arbitrary pressure and still serves
    let out = engine.refine_session(a, None, PrecisionPlan::uniform(16)).unwrap();
    assert_eq!(out.exec.logits, expect_logits(&xa, &[0, 1], 1, 16));
}

#[test]
fn unpinning_restores_lru_discipline() {
    let mock = mock_backend();
    let engine =
        Engine::spawn_with(mock_factory(&mock), EngineConfig { pool_cap: 2, ..Default::default() }).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let a = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap().session.unwrap();
    engine.pin_session(a, true).unwrap();
    engine.pin_session(a, false).unwrap();
    let _b = engine.begin_session(plan.clone(), image(2.0, 2), 2, 2).unwrap();
    let _c = engine.begin_session(plan.clone(), image(3.0, 2), 2, 3).unwrap();
    let msg = format!(
        "{:#}",
        engine.refine_session(a, None, PrecisionPlan::uniform(16)).unwrap_err()
    );
    assert!(msg.contains("evicted"), "an unpinned session rejoins the LRU order: {msg}");
}

#[test]
fn fully_pinned_pool_evicts_newcomers_by_name() {
    // the registry's admission problem: when every slot is pinned, a new
    // keep-session cannot be admitted — it is bounced immediately with a
    // named retryable `(overloaded)` refusal (and a later use names
    // that), rather than growing the pool unboundedly
    let mock = mock_backend();
    let engine =
        Engine::spawn_with(mock_factory(&mock), EngineConfig { pool_cap: 2, ..Default::default() }).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let g = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap().session.unwrap();
    let h = engine.begin_session(plan.clone(), image(2.0, 2), 2, 2).unwrap().session.unwrap();
    engine.pin_session(g, true).unwrap();
    engine.pin_session(h, true).unwrap();
    let i = engine.begin_session(plan, image(3.0, 2), 2, 3).unwrap().session.unwrap();
    assert_eq!(engine.stats().sessions_open(), 2, "pinned slots hold, newcomer bounced");
    assert_eq!(
        engine.stats().pool_bounces.load(Ordering::SeqCst),
        1,
        "the bounce is counted apart from LRU evictions"
    );
    let msg = format!(
        "{:#}",
        engine.refine_session(i, None, PrecisionPlan::uniform(16)).unwrap_err()
    );
    assert!(
        msg.contains("bounced") && msg.contains("(overloaded)"),
        "the bounced newcomer must carry the retryable overload marker: {msg}"
    );
    // pinning the bounced newcomer fails loudly with the same reason
    let msg = format!("{:#}", engine.pin_session_checked(i, true).unwrap_err());
    assert!(
        msg.contains("cannot pin") && msg.contains("(overloaded)"),
        "a checked pin on a bounced session must surface the refusal: {msg}"
    );
    // both pinned sessions still serve
    assert!(engine.refine_session(g, None, PrecisionPlan::uniform(16)).is_ok());
    assert!(engine.refine_session(h, None, PrecisionPlan::uniform(16)).is_ok());
}

#[test]
fn submit_frame_rebases_the_pooled_session_bit_identically() {
    let psb = tiny_psbnet();
    let engine =
        Engine::spawn(psb::backend::sim_factory(psb.clone(), psb::rng::RngKind::Philox)).unwrap();
    let (h, w, c) = psb.input_hwc;
    let img = h * w * c;
    let mk_x = |tag: f32| -> Vec<f32> {
        (0..2 * img).map(|i| (tag + i as f32 * 0.37).sin().abs()).collect()
    };
    let (x0, x1, x2) = (mk_x(0.3), mk_x(5.0), mk_x(9.0));
    let id = engine
        .begin_session(PrecisionPlan::uniform(4), x0, 2, 7)
        .unwrap()
        .session
        .unwrap();
    engine.pin_session(id, true).unwrap();
    let f1 = engine.submit_frame(id, x1.clone()).unwrap();
    assert_eq!(f1.session, Some(id), "the stream session stays pooled across frames");
    let f2 = engine.submit_frame(id, x2.clone()).unwrap();
    assert_eq!(engine.stats().stream_frames.load(Ordering::SeqCst), 2);
    // oracle: fresh dedicated sessions on each frame, same seed
    let oracle = |x: &Vec<f32>| -> Vec<f32> {
        let backend = SimBackend::new(psb.clone());
        let mut sess = backend.open(&PrecisionPlan::uniform(4)).unwrap();
        sess.begin(&Tensor::from_vec(x.clone(), &[2, h, w, c]), 7).unwrap();
        sess.logits().data.clone()
    };
    assert_eq!(f1.exec.logits, oracle(&x1), "frame 1 rebase ≡ fresh begin");
    assert_eq!(f2.exec.logits, oracle(&x2), "frame 2 rebase ≡ fresh begin");
}

#[test]
fn submit_frame_failures_answer_named_errors_never_dropped_replies() {
    // 1. a backend whose sessions cannot rebase: the frame fails with
    //    the backend's message, the session is retired with the cause
    let mock = mock_backend();
    let engine = Engine::spawn(mock_factory(&mock)).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let id = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap().session.unwrap();
    let msg = format!("{:#}", engine.submit_frame(id, image(2.0, 2)).unwrap_err());
    assert!(msg.contains("cannot rebase"), "capability gap must be loud: {msg}");
    let msg = format!("{:#}", engine.submit_frame(id, image(3.0, 2)).unwrap_err());
    assert!(
        msg.contains("dropped by a failed frame rebase"),
        "later frames must name the retirement: {msg}"
    );
    // 2. frames on closed / unknown sessions name what happened
    let id2 = engine.begin_session(plan, image(4.0, 2), 2, 2).unwrap().session.unwrap();
    engine.close_session(id2).unwrap();
    let msg = format!("{:#}", engine.submit_frame(id2, image(5.0, 2)).unwrap_err());
    assert!(msg.contains("was closed"), "frame-after-close must name the close: {msg}");
    // 3. malformed frame geometry is rejected before touching the pool
    let psb = tiny_psbnet();
    let engine =
        Engine::spawn(psb::backend::sim_factory(psb.clone(), psb::rng::RngKind::Philox)).unwrap();
    let (h, w, c) = psb.input_hwc;
    let x0: Vec<f32> = (0..h * w * c).map(|i| i as f32 * 0.01).collect();
    let id = engine
        .begin_session(PrecisionPlan::uniform(4), x0, 1, 3)
        .unwrap()
        .session
        .unwrap();
    assert!(engine.submit_frame(id, vec![0.0; 5]).is_err(), "ragged frame must be rejected");
    // …and the session survived the rejection
    let ok: Vec<f32> = (0..h * w * c).map(|i| i as f32 * 0.02).collect();
    assert!(engine.submit_frame(id, ok).is_ok());
}

#[test]
fn stream_registry_reclaims_idle_streams_with_a_named_reason() {
    use psb::coordinator::{Clock, Metrics, StreamConfig, StreamRegistry, Supervisor, SupervisorConfig};
    let psb = tiny_psbnet();
    let engine = Arc::new(
        Engine::spawn(psb::backend::sim_factory(psb.clone(), psb::rng::RngKind::Philox)).unwrap(),
    );
    let (h, w, c) = psb.input_hwc;
    let img = h * w * c;
    let metrics = Arc::new(Metrics::default());
    let supervisor =
        Arc::new(Supervisor::new(engine.clone(), Clock::real(), SupervisorConfig::default(), 2));
    let registry = StreamRegistry::new(
        engine.clone(),
        supervisor,
        metrics.clone(),
        img,
        2,
        StreamConfig { idle_ttl: std::time::Duration::ZERO, ..Default::default() },
        Clock::real(),
        Arc::new(psb::coordinator::BrownoutController::new(
            psb::coordinator::BrownoutConfig::default(),
            Clock::real(),
        )),
    );
    let frame = |tag: f32| -> Vec<f32> { (0..img).map(|i| (tag + i as f32 * 0.31).abs() % 1.0).collect() };
    // stream 1 opens and serves; its second frame is a rebase (the
    // sweep spares the stream being served even at a zero TTL)
    let r = registry.submit_frame(1, frame(0.2)).unwrap();
    assert_eq!(r.served, psb::coordinator::ServedVia::Stream);
    let r = registry.submit_frame(1, frame(0.4)).unwrap();
    assert_eq!(r.served, psb::coordinator::ServedVia::Stream);
    assert_eq!(registry.frames(1), Some(2));
    assert_eq!(registry.live_streams(), 1);
    // a submit on another stream sweeps: with a zero TTL, stream 1 is
    // now idle-reclaimed (its pinned session released)
    registry.submit_frame(2, frame(0.5)).unwrap();
    let msg = format!("{:#}", registry.submit_frame(1, frame(0.7)).unwrap_err());
    assert!(
        msg.contains("reclaimed") && msg.contains("idle"),
        "frames on a reclaimed stream must carry the reclaim reason: {msg}"
    );
    // close() forgets the retirement; the id becomes usable again
    registry.close(1).unwrap();
    let r = registry.submit_frame(1, frame(0.9)).unwrap();
    assert_eq!(r.served, psb::coordinator::ServedVia::Stream);
    // reuse accounting flowed into the serving metrics
    assert!(metrics.stream_frames.load(Ordering::SeqCst) >= 1);
}

#[test]
fn stream_registry_reclaims_on_virtual_clock_ttl() {
    use psb::coordinator::{Clock, Metrics, StreamConfig, StreamRegistry, Supervisor, SupervisorConfig};
    let psb = tiny_psbnet();
    let engine = Arc::new(
        Engine::spawn(psb::backend::sim_factory(psb.clone(), psb::rng::RngKind::Philox)).unwrap(),
    );
    let (h, w, c) = psb.input_hwc;
    let img = h * w * c;
    let clock = Clock::virtual_clock();
    let metrics = Arc::new(Metrics::default());
    let supervisor =
        Arc::new(Supervisor::new(engine.clone(), clock.clone(), SupervisorConfig::default(), 2));
    let ttl = std::time::Duration::from_secs(10);
    let registry = StreamRegistry::new(
        engine.clone(),
        supervisor,
        metrics.clone(),
        img,
        2,
        StreamConfig { idle_ttl: ttl, ..Default::default() },
        clock.clone(),
        Arc::new(psb::coordinator::BrownoutController::new(
            psb::coordinator::BrownoutConfig::default(),
            clock.clone(),
        )),
    );
    let frame = |tag: f32| -> Vec<f32> { (0..img).map(|i| (tag + i as f32 * 0.31).abs() % 1.0).collect() };
    registry.submit_frame(1, frame(0.2)).unwrap();
    registry.submit_frame(2, frame(0.3)).unwrap();
    assert_eq!(registry.live_streams(), 2);
    // virtual time is explicit: no amount of real waiting reclaims
    std::thread::sleep(std::time::Duration::from_millis(5));
    registry.submit_frame(2, frame(0.4)).unwrap();
    assert_eq!(registry.live_streams(), 2, "no virtual time passed — nothing is idle");
    // advance past the TTL; the next submit's sweep reclaims stream 1
    // (stream 2 is the one being served, so the sweep spares it)
    clock.advance(ttl + std::time::Duration::from_secs(1));
    registry.submit_frame(2, frame(0.5)).unwrap();
    assert_eq!(registry.live_streams(), 1);
    let msg = format!("{:#}", registry.submit_frame(1, frame(0.6)).unwrap_err());
    assert!(
        msg.contains("reclaimed") && msg.contains("idle"),
        "virtual-clock TTL reclaim must carry the named reason: {msg}"
    );
}

// ---- panic containment + supervised recovery under pooling --------------

/// A backend whose `refine` panics outright — the harshest failure a
/// backend thread can produce.  The engine must contain the unwind
/// (`no_unwind`), name it, and keep serving.
#[derive(Clone)]
struct PanickyRefine;

struct PanickySession {
    plan: PrecisionPlan,
    x: Vec<f32>,
    rows: usize,
    seed: u64,
    logits: Tensor,
    report: CostReport,
}

impl InferenceSession for PanickySession {
    fn begin(&mut self, x: &Tensor, seed: u64) -> Result<StepReport> {
        self.x = x.data.clone();
        self.rows = x.shape[0];
        self.seed = seed;
        let n = self.plan.uniform_n().ok_or_else(|| anyhow!("uniform-only"))?;
        let mut data = Vec::with_capacity(self.rows * NC);
        for r in 0..self.rows {
            data.extend_from_slice(&mock_logit(&self.x[r * IMG..(r + 1) * IMG], self.seed, n));
        }
        self.logits = Tensor::from_vec(data, &[self.rows, NC]);
        Ok(StepReport::default())
    }

    fn refine(&mut self, _target: &PrecisionPlan) -> Result<StepReport> {
        panic!("synthetic backend crash in refine");
    }

    fn narrow(&mut self, _rows: &[usize]) -> Result<()> {
        Ok(())
    }

    fn logits(&self) -> &Tensor {
        &self.logits
    }

    fn feat(&self) -> Option<&Tensor> {
        None
    }

    fn plan(&self) -> &PrecisionPlan {
        &self.plan
    }

    fn cost_report(&self) -> &CostReport {
        &self.report
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl Backend for PanickyRefine {
    fn name(&self) -> &'static str {
        "panicky"
    }

    fn input_hwc(&self) -> (usize, usize, usize) {
        (H, W, C)
    }

    fn open(&self, plan: &PrecisionPlan) -> Result<Box<dyn InferenceSession>> {
        Ok(Box::new(PanickySession {
            plan: plan.clone(),
            x: Vec::new(),
            rows: 0,
            seed: 0,
            logits: Tensor::zeros(&[0]),
            report: CostReport::default(),
        }))
    }
}

#[test]
fn panicking_backend_is_contained_named_and_the_pool_keeps_serving() {
    let engine =
        Engine::spawn(Box::new(|| Ok(Box::new(PanickyRefine) as Box<dyn Backend>))).unwrap();
    let plan = PrecisionPlan::uniform(8);
    let a = engine.begin_session(plan.clone(), image(1.0, 2), 2, 1).unwrap();
    // the refine panics inside the backend; the engine thread must NOT
    // die — the unwind is contained and converted to a named error
    let err = engine
        .refine_session(a.session.unwrap(), None, PrecisionPlan::uniform(16))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("panicked during") && msg.contains("synthetic backend crash"),
        "the panic payload must surface in the named error: {msg}"
    );
    assert!(msg.contains("(transient)"), "contained panics are retryable faults: {msg}");
    // the error ring kept it
    let recent = engine.recent_errors();
    assert!(
        recent.iter().any(|e| e.contains("synthetic backend crash")),
        "recent_errors must retain the panic: {recent:?}"
    );
    // and the engine thread survived: begins still serve
    let again = engine.begin_session(plan, image(2.0, 2), 2, 2).unwrap();
    assert_eq!(again.exec.logits.len(), 2 * NC, "engine must keep serving after a panic");
}

#[test]
fn eviction_during_inflight_escalation_resurrects_bit_identically() {
    use psb::coordinator::{Clock, Supervisor, SupervisorConfig};
    // a cap-2 pool under pressure: session `a` is evicted between its
    // stage-1 pass and its escalation.  Unsupervised, that escalation is
    // a named failure (`evicted_sessions_name_the_eviction_in_last_error`
    // above); supervised, the recorded (plan, x, batch, seed) provenance
    // resurrects the session and the refine replays bit-identically.
    let mock = mock_backend();
    let engine = Arc::new(
        Engine::spawn_with(mock_factory(&mock), EngineConfig { pool_cap: 2, ..Default::default() }).unwrap(),
    );
    let clock = Clock::virtual_clock(); // backoff advances virtually: no real sleeps
    let supervisor =
        Arc::new(Supervisor::new(engine.clone(), clock, SupervisorConfig::default(), NC));
    let plan8 = PrecisionPlan::uniform(8);
    let xa = image(1.0, 4);
    let (a, recovered) = supervisor.begin_session(plan8.clone(), xa.clone(), 4, 5).unwrap();
    assert!(!recovered, "clean begin needs no recovery");
    let a_id = a.session.unwrap();
    // pool pressure evicts `a` while its escalation is still pending
    engine.begin_session(plan8.clone(), image(2.0, 4), 4, 6).unwrap();
    engine.begin_session(plan8, image(3.0, 4), 4, 7).unwrap();
    let ticket = supervisor.submit_refine(a_id, vec![0, 2], PrecisionPlan::uniform(16)).unwrap();
    let (out, resurrected) = supervisor.await_refine(ticket).unwrap();
    assert!(resurrected, "the evicted session must have been resurrected");
    assert_eq!(
        out.exec.logits,
        expect_logits(&xa, &[0, 2], 5, 16),
        "the resurrected escalation must be bit-identical to the never-evicted pass"
    );
    use std::sync::atomic::Ordering::Relaxed;
    assert!(supervisor.stats().resurrections.load(Relaxed) >= 1);
    assert!(supervisor.stats().faults_seen.load(Relaxed) >= 1);
}

// ---- helpers ------------------------------------------------------------

fn tiny_psbnet() -> PsbNetwork {
    let mut net = Network::new((8, 8, 3), "pool-test");
    let c1 = net.add(Op::Conv { k: 3, stride: 2, cin: 3, cout: 4 }, vec![0], "c1");
    let r1 = net.add(Op::ReLU, vec![c1], "r1");
    net.feat_node = Some(r1);
    let g = net.add(Op::GlobalAvgPool, vec![r1], "gap");
    net.add(Op::Dense { cin: 4, cout: 2 }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(3);
    net.init(&mut rng);
    PsbNetwork::prepare(&net, PsbOptions::default())
}
