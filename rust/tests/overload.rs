//! Overload properties: graceful degradation under synthetic bursts,
//! per docs/ROBUSTNESS.md ("Overload and brownout").  The contracts:
//!
//! 1. **Reply conservation** — every submit is accounted for exactly
//!    once: refused synchronously with a named `(overloaded)` error, or
//!    answered, or shed with a named error.  Nothing hangs, nothing is
//!    silently dropped, and goodput never reaches zero while the engine
//!    is healthy.
//! 2. **Deadline shedding bills zero** — a request whose queue wait
//!    exceeds its budget is removed at dequeue, before any backend
//!    work, on the virtual clock.
//! 3. **Brownout degradation is bit-exact** — a `Stage1Only` brownout
//!    serves the same bits a stage-1-only (escalation-disabled) server
//!    would, flagged `ServedVia::Degraded`.
//! 4. **Streams coalesce under brownout** — stale queued frames lose to
//!    the newest arrival with a named, counted reason.
//! 5. **A fully pinned pool refuses new streams by name** — a retryable
//!    `(overloaded)` bounce, never an unbounded pool or a dropped reply.

use std::sync::Arc;
use std::time::Duration;

use psb::backend::{chaos_factory, sim_factory, ChaosConfig};
use psb::coordinator::{
    is_overloaded, BatcherConfig, BrownoutConfig, BrownoutLevel, Clock, Coordinator,
    CoordinatorConfig, EscalationPolicy, ServedVia,
};
use psb::rng::{RngKind, Xorshift128Plus};
use psb::sim::network::{Network, Op};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};

const IMG: usize = 8 * 8 * 3;
const NC: usize = 2;

fn tiny_psbnet() -> PsbNetwork {
    let mut net = Network::new((8, 8, 3), "overload-test");
    let c1 = net.add(Op::Conv { k: 3, stride: 2, cin: 3, cout: 4 }, vec![0], "c1");
    let r1 = net.add(Op::ReLU, vec![c1], "r1");
    net.feat_node = Some(r1);
    let g = net.add(Op::GlobalAvgPool, vec![r1], "gap");
    net.add(Op::Dense { cin: 4, cout: NC }, vec![g], "fc");
    let mut rng = Xorshift128Plus::seed_from(3);
    net.init(&mut rng);
    PsbNetwork::prepare(&net, PsbOptions::default())
}

fn image(tag: f32) -> Vec<f32> {
    (0..IMG).map(|i| ((i as f32) * 0.013 + tag).sin() * 0.5).collect()
}

fn stat(v: &std::sync::atomic::AtomicU64) -> u64 {
    v.load(std::sync::atomic::Ordering::Relaxed)
}

/// A deterministic *slow* backend: every op succeeds bit-exactly but
/// sleeps `op` of real time first — load without faults, so every
/// divergence from clean serving is the overload layer's doing.
fn slow_factory(op: Duration) -> psb::backend::BackendFactory {
    let cfg = ChaosConfig {
        seed: 1,
        transient_permille: 0,
        permanent_permille: 0,
        slow_permille: 1000,
        poison_permille: 0,
        geometry_permille: 0,
        slow_op: op,
    };
    let (factory, _stats) = chaos_factory(sim_factory(tiny_psbnet(), RngKind::Xorshift), cfg);
    factory
}

// ------------------------------------------------- reply conservation

/// A burst far past the admission cap into a slow (but healthy, fault
/// free) engine: submits are conserved exactly across
/// answered/refused/errored, the brownout ladder visibly engages, the
/// breaker stays closed (overload is not a fault), and after the burst
/// the ladder walks back down to full service on the virtual clock.
#[test]
fn burst_conserves_replies_and_the_ladder_recovers() {
    const N: usize = 128;
    let clock = Clock::virtual_clock();
    let coord = Coordinator::start_with_factory(
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            // linger ZERO: partial batches depart immediately, so the
            // virtual clock needs no advancing for the burst to drain
            batcher: BatcherConfig {
                batch_size: 4,
                linger: Duration::ZERO,
                shed_after: None,
            },
            // n_high == n_low: no stage-2 traffic, the burst exercises
            // admission + ladder alone
            policy: EscalationPolicy { n_low: 4, n_high: 4, ..Default::default() },
            seed: 5,
            pool_cap: 8,
            stream_idle_ttl: Duration::from_secs(30),
            supervisor: Default::default(),
            admission_cap: 8,
            brownout: BrownoutConfig {
                high_milli: 500,
                low_milli: 250,
                dwell_up: Duration::ZERO,
                dwell_down: Duration::from_millis(5),
                ..Default::default()
            },
            clock: clock.clone(),
        },
        slow_factory(Duration::from_millis(2)),
        IMG,
        NC,
        1_000,
    )
    .unwrap();

    // -- burst: N submits far faster than the 2ms-per-pass engine drains
    let mut refused = 0usize;
    let mut inflight = Vec::with_capacity(N);
    for i in 0..N {
        match coord.submit(image(i as f32 * 0.05)) {
            Ok(rx) => inflight.push(rx),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(is_overloaded(&msg), "refusals must carry (overloaded): {msg}");
                refused += 1;
            }
        }
    }
    let accepted = inflight.len();
    let mut answered = 0usize;
    let mut named_errors = 0usize;
    for (i, rx) in inflight.into_iter().enumerate() {
        match rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("accepted request {i} was dropped or hung"))
        {
            Ok(resp) => {
                assert!(resp.class < NC);
                answered += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(is_overloaded(&msg), "in-queue failures must be overload-named: {msg}");
                named_errors += 1;
            }
        }
    }
    // exact conservation: nothing dropped, nothing double-counted
    assert_eq!(refused + answered + named_errors, N);
    assert!(answered > 0, "goodput must never reach zero while the engine is healthy");
    assert!(refused > 0, "a {N}-burst into an 8-slot queue must refuse some admissions");
    assert!(
        stat(&coord.overload.stats.steps_up) >= 1,
        "the ladder must visibly engage under the burst"
    );
    let st = coord.supervisor.stats();
    assert_eq!(
        stat(&st.breaker_trips),
        0,
        "overload pushback must never trip the circuit breaker"
    );
    assert_eq!(
        stat(&coord.metrics.shed),
        refused as u64,
        "every synchronous refusal is counted as shed"
    );
    assert_eq!(stat(&coord.metrics.completed), answered as u64 + named_errors as u64);
    assert_eq!(
        coord.metrics.queue_wait.count(),
        answered as u64 + named_errors as u64,
        "every dequeued request lands in the queue-wait distribution"
    );

    // -- recovery: a post-burst trickle with advancing virtual time
    // walks the ladder back to Full (dwell_down hysteresis per rung)
    let mut trickle = Vec::new();
    for _ in 0..400 {
        if coord.overload.level() == BrownoutLevel::Full {
            break;
        }
        clock.advance(Duration::from_millis(2));
        std::thread::sleep(Duration::from_millis(1));
        if let Ok(rx) = coord.submit(image(0.5)) {
            trickle.push(rx);
        }
    }
    assert_eq!(
        coord.overload.level(),
        BrownoutLevel::Full,
        "the ladder must recover to full service after the burst (steps_down={})",
        stat(&coord.overload.stats.steps_down)
    );
    assert!(stat(&coord.overload.stats.steps_down) >= 1);
    for (i, rx) in trickle.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("trickle request {i} was dropped or hung"));
        assert!(resp.is_ok(), "post-burst trickle must serve cleanly: {resp:?}");
    }
    let summary = coord.metrics.summary();
    assert!(summary.contains("brownout="), "summary must surface the ladder: {summary}");
    assert!(summary.contains("qwait_p50="), "summary must surface queue waits: {summary}");
}

// ------------------------------------------- deadline shed at dequeue

/// Requests whose queue wait exceeds the deadline budget are shed at
/// dequeue — zero backend work, named `(overloaded)` replies — and the
/// whole scenario runs on the virtual clock with no real sleeps.
#[test]
fn deadline_shed_at_dequeue_bills_zero_backend_work() {
    let clock = Clock::virtual_clock();
    let coord = Coordinator::start_with_factory(
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            batcher: BatcherConfig {
                batch_size: 8,
                linger: Duration::from_millis(50),
                shed_after: Some(Duration::from_millis(100)),
            },
            policy: EscalationPolicy { n_low: 4, n_high: 4, ..Default::default() },
            seed: 5,
            pool_cap: 8,
            stream_idle_ttl: Duration::from_secs(30),
            supervisor: Default::default(),
            admission_cap: 64,
            brownout: BrownoutConfig::default(),
            clock: clock.clone(),
        },
        sim_factory(tiny_psbnet(), RngKind::Xorshift),
        IMG,
        NC,
        1_000,
    )
    .unwrap();

    // three requests enqueue at t=0; virtual time then jumps past the
    // linger AND the shed budget before any batch can form
    let stale: Vec<_> = (0..3).map(|i| coord.submit(image(i as f32)).unwrap()).collect();
    clock.advance(Duration::from_millis(200));
    for (i, rx) in stale.into_iter().enumerate() {
        let err = match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(Err(e)) => format!("{e:#}"),
            Ok(Ok(resp)) => panic!("stale request {i} must be shed, got answer {resp:?}"),
            Err(_) => panic!("stale request {i} was dropped or hung"),
        };
        assert!(is_overloaded(&err), "shed replies carry (overloaded): {err}");
        assert!(err.contains("shed at dequeue"), "shed replies name the mechanism: {err}");
    }
    // shed before any backend work: billed zero, engine never called
    assert_eq!(stat(&coord.metrics.engine_calls), 0, "shed requests must not reach the engine");
    assert_eq!(stat(&coord.metrics.gated_adds), 0, "shed requests are billed zero");
    assert_eq!(stat(&coord.metrics.samples_paid), 0);
    assert_eq!(stat(&coord.metrics.shed), 3);
    assert_eq!(stat(&coord.metrics.completed), 3, "a shed reply still completes the request");
    assert_eq!(coord.metrics.queue_wait.count(), 3, "shed waits land in the distribution");
    assert_eq!(coord.metrics.latency.count(), 0, "no served latency was recorded");

    // a fresh request after the jump is inside its budget: the linger
    // flush serves it normally
    let rx = coord.submit(image(9.0)).unwrap();
    clock.advance(Duration::from_millis(60));
    let resp = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("fresh request was dropped or hung")
        .expect("fresh request must serve after the stale ones shed");
    assert!(resp.class < NC);
    assert!(stat(&coord.metrics.engine_calls) >= 1, "the fresh request did reach the engine");
}

// ------------------------------------- bit-exact brownout degradation

/// A server browned out to `Stage1Only` answers bit-identically —
/// class and confidence bits — to a server with escalation disabled
/// outright: degraded *precision* is exactly stage-1 service, only
/// flagged.  (PSB answers are pure functions of `(plan, seed, input)`.)
#[test]
fn stage1_only_brownout_is_bit_identical_to_stage1_service() {
    const N: usize = 16;
    let mk = |pin: Option<BrownoutLevel>, disabled: bool| {
        Coordinator::start_with_factory(
            CoordinatorConfig {
                artifact_dir: "artifacts".into(),
                // batch_size 1 + serial submits: identical batch
                // composition and seed sequence across both servers
                batcher: BatcherConfig {
                    batch_size: 1,
                    linger: Duration::ZERO,
                    shed_after: None,
                },
                // threshold_scale 0: every request *wants* escalation,
                // so the brownout (or the disabled policy) must refuse
                // every one of them the same way
                policy: EscalationPolicy {
                    n_low: 4,
                    n_high: 16,
                    threshold_scale: 0.0,
                    disabled,
                    ..Default::default()
                },
                seed: 5,
                pool_cap: 8,
                stream_idle_ttl: Duration::from_secs(30),
                supervisor: Default::default(),
                admission_cap: 64,
                brownout: BrownoutConfig { pin_level: pin, ..Default::default() },
                clock: Clock::real(),
            },
            sim_factory(tiny_psbnet(), RngKind::Xorshift),
            IMG,
            NC,
            1_000,
        )
        .unwrap()
    };
    let browned = mk(Some(BrownoutLevel::Stage1Only), false);
    let oracle = mk(None, true);

    let mut degraded = 0usize;
    for i in 0..N {
        let x = image(i as f32 * 0.11);
        let a = browned.classify(x.clone()).unwrap();
        let b = oracle.classify(x).unwrap();
        assert_eq!(a.class, b.class, "request {i}: brownout changed the class");
        assert_eq!(
            a.confidence.to_bits(),
            b.confidence.to_bits(),
            "request {i}: brownout answer must be bit-identical to stage-1 service"
        );
        assert_eq!(a.n_used, 4, "request {i}: brownout serves the stage-1 n");
        assert!(!a.escalated, "request {i}: a brownout answer must not claim escalation");
        assert_eq!(b.served, ServedVia::Stage1);
        if a.served == ServedVia::Degraded {
            degraded += 1;
        } else {
            assert_eq!(a.served, ServedVia::Stage1, "request {i}: unexpected path {:?}", a.served);
        }
    }
    assert!(
        degraded > 0,
        "with a zero escalation threshold the brownout must have blocked escalations"
    );
    assert_eq!(
        stat(&browned.metrics.escalated),
        0,
        "no stage-2 work may be bought at Stage1Only"
    );
    assert_eq!(stat(&browned.supervisor.stats().degraded), degraded as u64);
}

// ------------------------------------------- stream frame coalescing

/// Under brownout, queued stream frames coalesce: when a newer frame
/// for the same stream has already arrived, the older queued one is
/// dropped with a named, counted `(overloaded)` reason — the newest
/// frame pays the rebase.
#[test]
fn brownout_coalesces_queued_stream_frames_latest_wins() {
    let coord = Arc::new(
        Coordinator::start_with_factory(
            CoordinatorConfig {
                artifact_dir: "artifacts".into(),
                batcher: BatcherConfig {
                    batch_size: 4,
                    linger: Duration::from_millis(1),
                    shed_after: None,
                },
                // n_high == n_low: frames never fork-escalate, each
                // frame is exactly one slow engine pass
                policy: EscalationPolicy { n_low: 4, n_high: 4, ..Default::default() },
                seed: 5,
                pool_cap: 8,
                stream_idle_ttl: Duration::from_secs(30),
                supervisor: Default::default(),
                admission_cap: 64,
                // pinned at CapEscalation: coalescing is on, nothing
                // else about the ladder moves during the test
                brownout: BrownoutConfig {
                    pin_level: Some(BrownoutLevel::CapEscalation),
                    ..Default::default()
                },
                clock: Clock::real(),
            },
            slow_factory(Duration::from_millis(300)),
            IMG,
            NC,
            1_000,
        )
        .unwrap(),
    );

    // frame 1 opens the stream (slow, ~300ms, but serial)
    let r1 = coord.submit_frame(7, image(0.1)).unwrap();
    assert_eq!(r1.served, ServedVia::Stream);

    // three frames race: A starts rebasing (holds the registry for
    // ~300ms), B and the main thread queue behind it in arrival order
    let ca = coord.clone();
    let a = std::thread::spawn(move || ca.submit_frame(7, image(0.2)));
    std::thread::sleep(Duration::from_millis(100));
    let cb = coord.clone();
    let b = std::thread::spawn(move || cb.submit_frame(7, image(0.3)));
    std::thread::sleep(Duration::from_millis(100));
    let main_res = coord.submit_frame(7, image(0.4));

    let results = [a.join().unwrap(), b.join().unwrap(), main_res];
    let mut ok = 0usize;
    let mut coalesced = 0usize;
    for r in &results {
        match r {
            Ok(resp) => {
                assert!(resp.class < NC);
                ok += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(is_overloaded(&msg), "a coalesced frame is overload-named: {msg}");
                assert!(msg.contains("latest frame wins"), "the reason names the policy: {msg}");
                coalesced += 1;
            }
        }
    }
    assert_eq!(ok + coalesced, 3, "every frame call resolves exactly once");
    assert!(ok >= 1, "the newest queued frame must be served");
    assert!(coalesced >= 1, "an overtaken queued frame must be coalesced away");
    assert_eq!(
        stat(&coord.metrics.frames_coalesced),
        coalesced as u64,
        "every coalesced frame is counted, nothing else is"
    );
    // the stream survives coalescing: the next frame serves normally
    let r = coord.submit_frame(7, image(0.5)).unwrap();
    assert_eq!(r.served, ServedVia::Stream);
}

// ------------------------------------------- fully pinned pool bounce

/// With every pool slot pinned by a live stream, opening another stream
/// answers a named retryable `(overloaded)` refusal — the pool never
/// grows past its bound and the refusal is counted — while the live
/// stream keeps serving.
#[test]
fn fully_pinned_pool_refuses_new_streams_by_name() {
    let coord = Coordinator::start_with_factory(
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            batcher: BatcherConfig {
                batch_size: 4,
                linger: Duration::from_millis(1),
                shed_after: None,
            },
            policy: EscalationPolicy { n_low: 4, n_high: 4, ..Default::default() },
            seed: 5,
            // one slot: the first stream pins it, the second must bounce
            pool_cap: 1,
            stream_idle_ttl: Duration::from_secs(30),
            supervisor: Default::default(),
            admission_cap: 64,
            brownout: BrownoutConfig::default(),
            clock: Clock::real(),
        },
        sim_factory(tiny_psbnet(), RngKind::Xorshift),
        IMG,
        NC,
        1_000,
    )
    .unwrap();

    let r = coord.submit_frame(0, image(0.1)).unwrap();
    assert_eq!(r.served, ServedVia::Stream);

    let err = match coord.submit_frame(1, image(0.2)) {
        Err(e) => format!("{e:#}"),
        Ok(resp) => panic!("a fully pinned pool must refuse the new stream, got {resp:?}"),
    };
    assert!(is_overloaded(&err), "the bounce must be retryable by name: {err}");
    assert!(err.contains("could not open"), "the refusal names the stream open: {err}");
    assert_eq!(
        stat(&coord.metrics.pool_bounces),
        1,
        "the capacity refusal is counted apart from LRU evictions"
    );

    // the pinned stream is untouched and keeps serving frames
    let r = coord.submit_frame(0, image(0.3)).unwrap();
    assert_eq!(r.served, ServedVia::Stream);
    assert_eq!(coord.stream.live_streams(), 1);

    // …and once the first stream closes, the slot frees up for a retry
    coord.close_stream(0).unwrap();
    let r = coord.submit_frame(1, image(0.4)).unwrap();
    assert_eq!(r.served, ServedVia::Stream);
}
