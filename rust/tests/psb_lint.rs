//! `psb-lint` self-tests: lexer unit tests, one fixture per rule proving
//! it fires (with the right `file:line`), waiver semantics, the
//! target-manifest cross-check, and finally the linter run over this
//! repo itself — which must come back clean under the shipped waivers.

use psb::analysis::lexer::{lex, Tok};
use psb::analysis::manifest::{check, parse_targets, TargetKind};
use psb::analysis::{lint_repo, lint_source_complete, to_json, Finding, RuleId};

// ---------------------------------------------------------------- lexer

#[test]
fn lexer_separates_comments_from_tokens() {
    let lx = lex("// leading\nlet x = 1; // trailing\n");
    assert_eq!(lx.comments.len(), 2);
    assert_eq!(lx.comments[0].line, 1);
    assert_eq!(lx.comments[0].text, "// leading");
    assert_eq!(lx.comments[1].line, 2);
    let idents: Vec<_> = lx
        .tokens
        .iter()
        .filter_map(|t| match &t.tok {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        })
        .collect();
    assert_eq!(idents, ["let", "x"]);
}

#[test]
fn lexer_handles_nested_block_comments() {
    let lx = lex("/* a /* nested */ b */ let y = 2;");
    assert_eq!(lx.comments.len(), 1);
    assert!(lx.comments[0].text.contains("nested"));
    assert!(matches!(lx.tokens[0].tok, Tok::Ident(ref s) if s == "let"));
}

#[test]
fn lexer_raw_strings_hide_their_contents() {
    // a raw string whose *contents* look like a comment and a waiver —
    // neither may surface as a Comment
    let src = r##"let s = r#"// psb-lint: allow(unsafe): not real"#;"##;
    let lx = lex(src);
    assert!(lx.comments.is_empty(), "raw string leaked a comment");
    assert_eq!(lx.tokens.iter().filter(|t| t.tok == Tok::Str).count(), 1);
}

#[test]
fn lexer_strings_hide_their_contents() {
    let lx = lex(r#"let s = "HashMap::new() // not a comment"; let b = b"x";"#);
    assert!(lx.comments.is_empty());
    assert_eq!(lx.tokens.iter().filter(|t| t.tok == Tok::Str).count(), 2);
    // the HashMap inside the string must NOT be an ident token
    assert!(!lx
        .tokens
        .iter()
        .any(|t| matches!(&t.tok, Tok::Ident(s) if s == "HashMap")));
}

#[test]
fn lexer_chars_vs_lifetimes() {
    let lx = lex(r"fn f<'a>(c: char) { let x = 'x'; let n = '\n'; }");
    assert_eq!(lx.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count(), 1);
    assert_eq!(lx.tokens.iter().filter(|t| t.tok == Tok::Char).count(), 2);
}

#[test]
fn lexer_float_vs_int_literals() {
    let lx = lex("let a = 1; let b = 1.5; let c = 1e3; let d = 2f32; let e = 0x1F; let g = 1.max(2);");
    let floats = lx.tokens.iter().filter(|t| t.tok == Tok::Float).count();
    let ints = lx.tokens.iter().filter(|t| t.tok == Tok::Int).count();
    assert_eq!(floats, 3, "1.5, 1e3, 2f32");
    assert_eq!(ints, 4, "1, 0x1F, 1 (recv of .max), 2");
}

#[test]
fn lexer_line_numbers_are_accurate() {
    let lx = lex("let a = 1;\n\nlet b = 2.0;\n");
    let float = lx.tokens.iter().find(|t| t.tok == Tok::Float).unwrap();
    assert_eq!(float.line, 3);
}

// ------------------------------------------------------------ rule fixtures

fn rules_of(findings: &[Finding]) -> Vec<(RuleId, u32)> {
    findings.iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn float_purity_fires_in_the_intkernel() {
    let src = "fn quantize(x: f32) -> i32 {\n    (x * 65536.0) as i32\n}\n";
    let f = lint_source_complete("rust/src/backend/intkernel/fake.rs", src);
    assert_eq!(
        rules_of(&f),
        [(RuleId::FloatPurity, 1), (RuleId::FloatPurity, 2)],
        "{f:?}"
    );
    assert!(
        f[0].to_string().starts_with("rust/src/backend/intkernel/fake.rs:1: [float-purity]"),
        "{}",
        f[0]
    );
    // the same source outside the IntKernel is fine
    let f = lint_source_complete("rust/src/sim/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn float_purity_skips_test_code() {
    let src = "#[cfg(test)]\nmod tests {\n    fn t() { let x = 1.0f32; }\n}\n";
    let f = lint_source_complete("rust/src/backend/intkernel/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
    // …but #[cfg(not(test))] code is NOT test code
    let src = "#[cfg(not(test))]\nmod prod {\n    fn t() { let x = 1.0f32; }\n}\n";
    let f = lint_source_complete("rust/src/backend/intkernel/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::FloatPurity, 3)], "{f:?}");
}

#[test]
fn determinism_bans_unordered_maps_and_clocks() {
    let src = "use std::collections::HashMap;\nfn t() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(
        rules_of(&f),
        [(RuleId::Determinism, 1), (RuleId::Determinism, 3)],
        "{f:?}"
    );
    // `Instant` without `::now` (type position, elapsed()) is fine
    assert!(f.iter().all(|x| x.line != 2));
    // out of scope: runtime/ is lookup-only
    let f = lint_source_complete("rust/src/runtime/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn determinism_bans_os_randomness() {
    let src = "fn seed() -> u64 {\n    let h = std::collections::hash_map::RandomState::new();\n    0\n}\n";
    let f = lint_source_complete("rust/src/sim/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::Determinism, 2)], "{f:?}");
}

#[test]
fn no_panic_fires_on_the_hot_path() {
    let src = r#"fn serve() {
    let v: Option<u32> = None;
    v.unwrap();
    v.expect("boom");
    panic!("down");
}
"#;
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(
        rules_of(&f),
        [(RuleId::NoPanic, 3), (RuleId::NoPanic, 4), (RuleId::NoPanic, 5)],
        "{f:?}"
    );
    assert!(f[0].message.contains("unwrap"), "{}", f[0].message);
    // identical code off the hot path is not flagged
    let f = lint_source_complete("rust/src/sim/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn no_panic_skips_test_code_and_non_calls() {
    let src = "#[test]\nfn t() {\n    Some(1).unwrap();\n}\nfn unwrap() {}\nfn prod() { unwrap(); }\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    // the free function `unwrap()` (no receiver dot) is not a finding
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn lock_hygiene_fires_on_raw_coordinator_locks() {
    let src = "fn peek(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    // the raw lock AND the unwrap of its PoisonError both fire
    assert_eq!(
        rules_of(&f),
        [(RuleId::NoPanic, 2), (RuleId::LockHygiene, 2)],
        "{f:?}"
    );
    assert!(f[1].message.contains("lock_unpoisoned"), "{}", f[1].message);
    // outside the coordinator the rule does not apply
    let f = lint_source_complete("rust/src/sim/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn lock_hygiene_skips_tests_waivers_and_non_calls() {
    let src = "#[cfg(test)]\nmod tests {\n    fn t(m: &std::sync::Mutex<u32>) { let _ = m.lock(); }\n}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
    // the one sanctioned raw lock (inside lock_unpoisoned itself) is waived
    let src = "// psb-lint: allow(lock-hygiene): the sanctioned wrapper's own lock\nfn w(m: &std::sync::Mutex<u32>) { let _ = m.lock(); }\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
    // `try_lock()` and a free `lock()` function are not this pattern
    let src = "fn lock() {}\nfn t(m: &std::sync::Mutex<u32>) { lock(); let _ = m.try_lock(); }\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn unsafe_is_banned_everywhere_even_in_tests() {
    let src = "#[cfg(test)]\nmod tests {\n    fn t() { let p = unsafe { 1 }; }\n}\n";
    let f = lint_source_complete("rust/src/sim/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::Unsafe, 3)], "{f:?}");
}

#[test]
fn bounded_channels_fires_on_raw_coordinator_channels() {
    let src = "use std::sync::mpsc;\nfn q() {\n    let (tx, rx) = mpsc::channel::<u32>();\n    let _ = (tx, rx);\n}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::BoundedChannels, 3)], "{f:?}");
    assert!(f[0].message.contains("bounded_queue"), "{}", f[0].message);
    // fully-qualified paths still end in `mpsc::channel(` and fire too
    let src = "fn q() { let (tx, rx) = std::sync::mpsc::channel::<u32>(); let _ = (tx, rx); }\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::BoundedChannels, 1)], "{f:?}");
    // outside the coordinator the rule does not apply
    let f = lint_source_complete("rust/src/sim/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn bounded_channels_spares_rendezvous_slots_tests_and_waivers() {
    // sync_channel(1) reply slots are the sanctioned rendezvous idiom
    let src = "use std::sync::mpsc;\nfn q() { let (tx, rx) = mpsc::sync_channel::<u32>(1); let _ = (tx, rx); }\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
    // test code is exempt
    let src = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = std::sync::mpsc::channel::<u32>(); }\n}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
    // the admission wrapper itself carries the one sanctioned waiver
    let src = "// psb-lint: allow(bounded-channels): the bounded wrapper's own raw channel\nfn w() { let _ = std::sync::mpsc::channel::<u32>(); }\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

// ---------------------------------------------------------------- waivers

#[test]
fn waiver_suppresses_next_line_and_same_line() {
    let src = "// psb-lint: allow(float-purity): Q16 boundary, floats stop here\nfn q(x: f32) -> i32 { x as i32 }\n";
    let f = lint_source_complete("rust/src/backend/intkernel/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
    let src = "use std::collections::HashMap; // psb-lint: allow(determinism): keys sorted before iteration\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn waiver_does_not_reach_two_lines_down() {
    let src = "// psb-lint: allow(float-purity): too far away\n\nfn q(x: f32) -> i32 { x as i32 }\n";
    let f = lint_source_complete("rust/src/backend/intkernel/fake.rs", src);
    // the f32 finding survives AND the waiver is flagged as unused
    assert_eq!(
        rules_of(&f),
        [(RuleId::Waiver, 1), (RuleId::FloatPurity, 3)],
        "{f:?}"
    );
}

#[test]
fn waiver_without_reason_is_an_error() {
    let src = "// psb-lint: allow(determinism)\nuse std::collections::HashMap;\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    // reasonless waiver does not suppress; it errors, and the HashMap still fires
    assert_eq!(
        rules_of(&f),
        [(RuleId::Waiver, 1), (RuleId::Determinism, 2)],
        "{f:?}"
    );
    assert!(f[0].message.contains("no reason"), "{}", f[0].message);
}

#[test]
fn waiver_with_unknown_rule_is_an_error() {
    let src = "// psb-lint: allow(speed): because fast\nfn f() {}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::Waiver, 1)], "{f:?}");
    assert!(f[0].message.contains("unknown rule `speed`"), "{}", f[0].message);
}

#[test]
fn unused_waiver_is_an_error() {
    let src = "// psb-lint: allow(no-panic): nothing here panics (exactly!)\nfn calm() {}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::Waiver, 1)], "{f:?}");
    assert!(f[0].message.contains("suppresses nothing"), "{}", f[0].message);
}

#[test]
fn waiver_meta_rule_is_not_waivable() {
    let src = "// psb-lint: allow(waiver): meta\nfn f() {}\n";
    let f = lint_source_complete("rust/src/coordinator/fake.rs", src);
    assert_eq!(rules_of(&f), [(RuleId::Waiver, 1)], "{f:?}");
    assert!(f[0].message.contains("unknown rule `waiver`"), "{}", f[0].message);
}

// --------------------------------------------------------- target manifest

#[test]
fn manifest_parses_target_sections() {
    let cargo = "[package]\nname = \"x\"\n\n[[bench]]\nname = \"a\"\npath = \"rust/benches/a.rs\"\n\n[[test]]\nname = \"b\"\npath = \"rust/tests/b.rs\"\n\n[[example]]\nname = \"c\"\npath = \"examples/c.rs\"\n";
    let entries = parse_targets(cargo);
    assert_eq!(entries.len(), 3);
    assert_eq!(entries[0].kind, TargetKind::Bench);
    assert_eq!(entries[0].path, "rust/benches/a.rs");
    assert_eq!(entries[0].line, 6);
    assert_eq!(entries[2].kind, TargetKind::Example);
}

#[test]
fn manifest_flags_orphans_and_dangling_entries() {
    let cargo = "[[bench]]\nname = \"a\"\npath = \"rust/benches/a.rs\"\n\n[[test]]\nname = \"b\"\npath = \"rust/tests/missing.rs\"\n";
    let entries = parse_targets(cargo);
    let files = vec!["rust/benches/a.rs".to_string(), "rust/benches/orphan.rs".to_string()];
    let f = check(&entries, &files);
    assert_eq!(f.len(), 2, "{f:?}");
    // the orphan bench file, anchored at its line 1
    assert_eq!(f[0].rule, RuleId::TargetManifest);
    assert_eq!(f[0].file, "rust/benches/orphan.rs");
    assert_eq!(f[0].line, 1);
    assert!(f[0].message.contains("[[bench]]"), "{}", f[0].message);
    // the dangling manifest entry, anchored at its Cargo.toml line
    assert_eq!(f[1].file, "Cargo.toml");
    assert_eq!(f[1].line, 7);
    assert!(f[1].message.contains("rust/tests/missing.rs"), "{}", f[1].message);
}

// ------------------------------------------------------------------- json

#[test]
fn json_report_shape() {
    let f = vec![Finding {
        rule: RuleId::Determinism,
        file: "rust/src/x.rs".into(),
        line: 7,
        message: "a \"quoted\" reason".into(),
    }];
    let j = to_json(&f);
    assert!(j.contains("\"rule\": \"determinism\""), "{j}");
    assert!(j.contains("\"line\": 7"), "{j}");
    assert!(j.contains("a \\\"quoted\\\" reason"), "{j}");
    assert!(j.contains("\"count\": 1"), "{j}");
    assert!(to_json(&[]).contains("\"count\": 0"));
}

// -------------------------------------------------------------- self-test

/// The linter over this repo itself: every invariant the rules encode
/// must actually hold, with every intentional boundary site explicitly
/// waived.  This is the same check CI's `lint` job runs via the binary.
#[test]
fn repo_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint_repo(root).expect("lint_repo walk failed");
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(
        findings.is_empty(),
        "psb-lint found {} issue(s) in the repo (listed above)",
        findings.len()
    );
}
