"""L2 model tests: shapes, float-vs-PSB convergence, pallas-vs-ref paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.psb import encode


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def x8():
    return jax.random.uniform(jax.random.PRNGKey(1), (8, M.IMG, M.IMG, 3))


def test_layer_shapes():
    shapes = M.layer_shapes()
    assert shapes[0] == ((27, 16), 16)
    assert shapes[1] == ((144, 32), 32)
    assert shapes[2] == ((288, 32), 32)
    assert shapes[3] == ((32, 10), 10)


def test_im2col_shapes():
    x = jnp.zeros((2, 32, 32, 3))
    assert M.im2col(x, 3, 1).shape == (2, 32, 32, 27)
    assert M.im2col(x, 3, 2).shape == (2, 16, 16, 27)


def test_im2col_matches_conv():
    """im2col + matmul == lax.conv with SAME padding."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 3, 4))
    cols = M.im2col(x, 3, 1)
    # im2col channel order is (di, dj, c) blocks -> matches HWIO reshape
    got = cols.reshape(-1, 27) @ w.reshape(27, 4)
    got = got.reshape(2, 8, 8, 4)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_forward_float_shapes(params, x8):
    logits, feat = M.forward_float(params, x8)
    assert logits.shape == (8, 10)
    assert feat.shape == (8, 8, 8, 32)


@pytest.mark.parametrize("n", [1, 16])
def test_forward_psb_shapes(params, x8, n):
    layers = M.encode_params(params)
    logits, feat = M.forward_psb(layers, x8, jax.random.PRNGKey(2), n)
    assert logits.shape == (8, 10)
    assert feat.shape == (8, 8, 8, 32)
    assert np.isfinite(np.asarray(logits)).all()


def test_psb_converges_to_float(params, x8):
    """Paper Fig. 3 in miniature: error decreases with n, small at n=64."""
    layers = M.encode_params(params)
    ref, _ = M.forward_float(params, x8)
    errs = {}
    for n in [1, 8, 64]:
        logits, _ = M.forward_psb(layers, x8, jax.random.PRNGKey(3), n)
        errs[n] = float(jnp.abs(logits - ref).mean())
    assert errs[64] < errs[1]
    assert errs[64] < 0.1, errs


def test_psb_pallas_matches_jnp_path(params, x8):
    """use_pallas=True and the ref path produce identical numbers (same key)."""
    layers = M.encode_params(params)
    a, fa = M.forward_psb(layers, x8, jax.random.PRNGKey(4), 8, use_pallas=True)
    b, fb = M.forward_psb(layers, x8, jax.random.PRNGKey(4), 8, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), atol=2e-3)


def test_encode_params_roundtrip(params):
    layers = M.encode_params(params)
    for lp, l in zip(params, layers):
        w = l.sign * jnp.exp2(l.exp) * (1.0 + l.prob)
        np.testing.assert_allclose(np.asarray(w), np.asarray(lp.w), rtol=2e-6, atol=1e-7)


def test_psb_batch_invariance(params):
    """Same image at different batch positions gets the same logits (shared filter sample)."""
    layers = M.encode_params(params)
    x1 = jax.random.uniform(jax.random.PRNGKey(9), (1, M.IMG, M.IMG, 3))
    x4 = jnp.tile(x1, (4, 1, 1, 1))
    l1, _ = M.forward_psb(layers, x4, jax.random.PRNGKey(10), 8)
    np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l1[3]), atol=1e-5)
