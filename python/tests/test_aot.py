"""AOT emission: HLO text artifacts parse-ably produced with correct meta."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    meta = aot.emit(out, sample_sizes=[4], batches=[2], verbose=False)
    return out, meta


def test_emits_hlo_text_not_proto(emitted):
    out, _ = emitted
    for name in ["float_b2.hlo.txt", "psb_n4_b2.hlo.txt"]:
        text = open(os.path.join(out, name)).read()
        # HLO *text* module: readable, with an ENTRY computation.
        assert text.lstrip().startswith("HloModule")
        assert "ENTRY" in text


def test_meta_signature(emitted):
    out, meta = emitted
    disk = json.load(open(os.path.join(out, "meta.json")))
    assert disk["modules"] == {
        "float_b2": {"batch": 2, "kind": "float"},
        "psb_n4_b2": {"batch": 2, "kind": "psb", "n": 4},
    }
    assert disk["layer_shapes"] == [
        {"weight": [27, 16], "bias": 16},
        {"weight": [144, 32], "bias": 32},
        {"weight": [288, 32], "bias": 32},
        {"weight": [32, 10], "bias": 10},
    ]
    assert meta["q16_scale"] == 1024


def test_psb_module_parameter_count(emitted):
    out, _ = emitted
    text = open(os.path.join(out, "psb_n4_b2.hlo.txt")).read()
    header = text.splitlines()[0]
    header = header[header.index("{(") : header.index("->")]
    # x + seed + 4 layers x (sign, exp, prob, bias) = 18 parameters
    assert header.count("f32[") + header.count("u32[") == 18, header


def test_stamp_written(emitted):
    out, _ = emitted
    assert os.path.exists(os.path.join(out, ".stamp"))
