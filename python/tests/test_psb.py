"""Properties of the PSB number system (paper Sec. 3.1/3.2) and samplers."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.psb import (
    Q16_SCALE,
    decode_mean,
    discretize_prob,
    encode,
    quantize_q16,
    sample_binomial_gumbel,
    sample_wbar,
)

finite_weights = st.floats(
    min_value=-30.0, max_value=30.0, allow_nan=False, allow_infinity=False
).filter(lambda w: w == 0.0 or abs(w) > 1e-6)


@settings(max_examples=200, deadline=None)
@given(w=finite_weights)
def test_encoding_is_bijective(w):
    """decode(encode(w)) == w: the representation is exact, not lossy (Sec. 1.1)."""
    enc = encode(jnp.float32(w))
    back = float(decode_mean(enc))
    assert abs(back - np.float32(w)) <= 4e-6 * max(1.0, abs(w))


@settings(max_examples=50, deadline=None)
@given(w=finite_weights.filter(lambda w: w != 0.0))
def test_encoding_ranges(w):
    enc = encode(jnp.float32(w))
    assert float(enc.sign) in (-1.0, 1.0)
    assert 0.0 <= float(enc.prob) < 1.0
    # 2^e <= |w| < 2^(e+1)
    assert float(jnp.exp2(enc.exp)) <= abs(np.float32(w)) * (1 + 1e-6)
    assert abs(np.float32(w)) < float(jnp.exp2(enc.exp + 1)) * (1 + 1e-6)


def test_zero_weight_encodes_to_zero():
    enc = encode(jnp.zeros((3,)))
    np.testing.assert_array_equal(np.asarray(decode_mean(enc)), np.zeros(3))


def test_unbiasedness_empirical():
    """E[wbar_n] = w (Eq. 8): empirical mean over many draws converges."""
    w = jnp.array([0.75, -3.0, 0.001, 12.5, -0.2])
    draws = jax.vmap(lambda k: sample_wbar(k, w, 4))(
        jax.random.split(jax.random.PRNGKey(0), 4000)
    )
    mean = np.asarray(draws).mean(axis=0)
    se = np.asarray(draws).std(axis=0) / np.sqrt(4000)
    assert (np.abs(mean - np.asarray(w)) <= 5 * se + 1e-6).all(), (mean, w)


def test_variance_bound():
    """Var(wbar_n) <= w^2 / (8 n)  (Eq. 10)."""
    for n in [1, 2, 8, 32]:
        w = jnp.array([0.9, -1.5, 3.0, 0.3, -0.07])
        draws = jax.vmap(lambda k: sample_wbar(k, w, n))(
            jax.random.split(jax.random.PRNGKey(n), 6000)
        )
        var = np.asarray(draws).var(axis=0)
        bound = np.asarray(w) ** 2 / (8.0 * n)
        assert (var <= bound * 1.15 + 1e-9).all(), (n, var, bound)


def test_binomial_gumbel_moments():
    """Gumbel-max sampler (supp. Eq. 15) has Binomial(n, p) moments."""
    n = 16
    p = jnp.array([0.0, 0.1, 0.5, 0.9, 0.999])
    ks = jax.vmap(lambda k: sample_binomial_gumbel(k, p, n))(
        jax.random.split(jax.random.PRNGKey(1), 8000)
    )
    ks = np.asarray(ks)
    np.testing.assert_allclose(ks.mean(0), n * np.asarray(p), atol=0.15)
    np.testing.assert_allclose(
        ks.var(0), n * np.asarray(p) * (1 - np.asarray(p)), atol=0.4
    )
    assert ks.min() >= 0 and ks.max() <= n
    assert (ks[:, 0] == 0).all()  # p=0 corner is exact


@settings(max_examples=50, deadline=None)
@given(
    p=st.floats(0.0, 0.999999),
    bits=st.sampled_from([1, 2, 3, 4, 6]),
)
def test_discretize_prob_grid(p, bits):
    q = float(discretize_prob(jnp.float32(p), bits))
    levels = 1 << bits
    assert 0.0 <= q < 1.0
    assert abs(q * levels - round(q * levels)) < 1e-5  # on-grid
    # nearest level, except near p->1 where the top level is clipped away
    # (the right boundary would belong to the next exponent)
    assert abs(q - p) <= 1.0 / levels + 1e-6


@settings(max_examples=100, deadline=None)
@given(v=st.floats(-100.0, 100.0, allow_nan=False))
def test_quantize_q16(v):
    q = float(quantize_q16(jnp.float32(v)))
    assert -32.0 <= q <= 32.0
    assert abs(q * Q16_SCALE - round(q * Q16_SCALE)) < 1e-3
    if -31.9 < v < 31.9:
        assert abs(q - v) <= 0.5 / Q16_SCALE + 1e-6


def test_quantize_idempotent():
    x = jax.random.uniform(jax.random.PRNGKey(2), (128,), minval=-40, maxval=40)
    q1 = quantize_q16(x)
    q2 = quantize_q16(q1)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
