"""L1 correctness: Pallas capacitor kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts: the tiled
kernel (with padding, K-innermost accumulation and in-tile dequant) must
match ref.py on the float32 carrier, across shapes, sample sizes and block
configurations.  Hypothesis sweeps the shape/parameter space.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.capacitor import capacitor_matmul, vmem_bytes
from compile.kernels.ref import capacitor_matmul_mean_ref, capacitor_matmul_ref
from compile.psb import encode, quantize_q16


def make_case(key, m, k, n_out, n):
    k1, k2 = jax.random.split(key)
    x = quantize_q16(jax.random.uniform(k1, (m, k), minval=-2.0, maxval=2.0))
    w = jax.random.normal(k2, (k, n_out)) * 0.5
    enc = encode(w)
    counts = jnp.round(enc.prob * n)  # deterministic counts: exactness check
    return x, enc, counts


@pytest.mark.parametrize("m,k,n_out", [(4, 8, 4), (16, 27, 16), (64, 144, 32), (130, 288, 32)])
@pytest.mark.parametrize("n", [1, 4, 16])
def test_kernel_matches_ref(m, k, n_out, n):
    x, enc, counts = make_case(jax.random.PRNGKey(m * 1000 + k + n), m, k, n_out, n)
    got = capacitor_matmul(x, enc.sign, enc.exp, counts, n)
    want = capacitor_matmul_ref(x, enc.sign, enc.exp, counts, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1.0 / 1024.0 + 1e-6)


@pytest.mark.parametrize("quantize", [True, False])
def test_kernel_quantize_flag(quantize):
    x, enc, counts = make_case(jax.random.PRNGKey(7), 8, 16, 8, 8)
    got = capacitor_matmul(x, enc.sign, enc.exp, counts, 8, quantize=quantize)
    want = capacitor_matmul_ref(x, enc.sign, enc.exp, counts, 8, quantize=quantize)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)
    if quantize:
        # every output value sits on the Q16 grid
        g = np.asarray(got) * 1024.0
        np.testing.assert_allclose(g, np.round(g), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n_out=st.integers(1, 40),
    n=st.sampled_from([1, 2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(m, k, n_out, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = quantize_q16(jax.random.uniform(k1, (m, k), minval=-4.0, maxval=4.0))
    w = jax.random.normal(k2, (k, n_out))
    enc = encode(w)
    counts = jnp.floor(jax.random.uniform(k3, (k, n_out)) * (n + 1)).clip(0, n)
    got = capacitor_matmul(x, enc.sign, enc.exp, counts, n)
    want = capacitor_matmul_ref(x, enc.sign, enc.exp, counts, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1.0 / 1024.0 + 1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_kernel_block_shape_invariance(bm, bn, bk):
    """Tiling is an implementation detail: result is block-shape independent."""
    x, enc, counts = make_case(jax.random.PRNGKey(11), 33, 50, 17, 16)
    base = capacitor_matmul(x, enc.sign, enc.exp, counts, 16)
    got = capacitor_matmul(x, enc.sign, enc.exp, counts, 16, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-6)


def test_mean_counts_recover_float_matmul():
    """With k = p*n exactly, the capacitor equals the folded float matmul."""
    key = jax.random.PRNGKey(3)
    x = quantize_q16(jax.random.uniform(key, (32, 64), minval=-1, maxval=1))
    w = jax.random.normal(jax.random.PRNGKey(4), (64, 16)) * 0.3
    enc = encode(w)
    n = 1 << 20  # huge n: k = round(p*n) makes k/n ~ p to 1e-6
    counts = jnp.round(enc.prob * n)
    got = capacitor_matmul(x, enc.sign, enc.exp, counts, n, quantize=False)
    want = x @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_mean_ref_is_unbiased_reconstruction():
    w = jnp.array([[0.37, -1.9], [3.0, 0.0]])
    enc = encode(w)
    x = jnp.eye(2)
    got = capacitor_matmul_mean_ref(x, enc.sign, enc.exp, enc.prob, quantize=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(w), rtol=1e-6)


def test_vmem_budget():
    """DESIGN §Perf: default tile residency stays under 2 MiB."""
    assert vmem_bytes() <= 2 * 1024 * 1024
