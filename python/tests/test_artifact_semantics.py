"""Artifact semantics: the lowered HLO is self-contained CPU-executable
(no Mosaic custom-calls from the Pallas kernel), deterministic per seed,
and the PSB module's output converges to the float module's with n."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.psb import quantize_q16


def test_psb_hlo_has_no_mosaic_custom_call(tmp_path):
    """interpret=True must lower the Pallas kernel to plain HLO ops —
    a Mosaic custom-call would be unloadable on the CPU PJRT client."""
    out = str(tmp_path)
    aot.emit(out, sample_sizes=[2], batches=[1], verbose=False)
    text = open(f"{out}/psb_n2_b1.hlo.txt").read()
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(jax.random.PRNGKey(3))
    layers = M.encode_params(params)
    x = jax.random.uniform(jax.random.PRNGKey(4), (4, M.IMG, M.IMG, 3))
    return params, layers, x


def test_forward_deterministic_per_key(setup):
    _, layers, x = setup
    a, _ = M.forward_psb(layers, x, jax.random.PRNGKey(9), 8)
    b, _ = M.forward_psb(layers, x, jax.random.PRNGKey(9), 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c, _ = M.forward_psb(layers, x, jax.random.PRNGKey(10), 8)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_psb_error_decreases_with_n(setup):
    params, layers, x = setup
    ref, _ = M.forward_float(params, x)
    errs = []
    for n in [1, 16, 256]:
        tot = 0.0
        for seed in range(3):
            got, _ = M.forward_psb(layers, x, jax.random.PRNGKey(seed), n)
            tot += float(jnp.abs(got - ref).mean())
        errs.append(tot / 3)
    assert errs[2] < errs[1] < errs[0], errs


def test_intermediates_respect_q16_range(setup):
    """Q16 saturates at ±32: the feature map must stay in range."""
    _, layers, x = setup
    _, feat = M.forward_psb(layers, x, jax.random.PRNGKey(1), 4)
    f = np.asarray(feat)
    assert f.min() >= -32.0 and f.max() <= 32.0
    # and on the Q16 grid (ReLU of Q16 values stays on-grid)
    g = f * 1024.0
    np.testing.assert_allclose(g, np.round(g), atol=1e-2)


def test_quantizer_matches_rust_grid():
    """Spot values shared with rust num::fixed unit tests — the two
    implementations must agree bit-for-bit on the carrier."""
    cases = {
        -35.0: -32.0,
        31.999: 32767.0 / 1024.0,
        0.3333: np.round(0.3333 * 1024.0) / 1024.0,
        -0.00049: -1.0 / 1024.0,  # -0.50176 rounds away from zero
        0.5 / 1024.0: 1.0 / 1024.0,  # exact tie: away from zero (rust f32::round)
    }
    for v, want in cases.items():
        got = float(quantize_q16(jnp.float32(v)))
        assert got == pytest.approx(want, abs=1e-7), (v, got, want)
