"""AOT lowering: JAX model -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the published xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).  The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Emits, under artifacts/:

    psb_n{N}_b{B}.hlo.txt    PSB forward at sample size N, batch B
    float_b{B}.hlo.txt       float32 baseline, batch B
    meta.json                input/output signature for the rust loader
    .stamp                   make freshness marker

Input order of every PSB module (all float32 unless noted):

    x[B,32,32,3], seed uint32[1],
    then per layer (conv1, conv2, conv3, dense):
        sign[K,N], exp[K,N], prob[K,N], bias[N]

The float module takes x then per layer (w[K,N], bias[N]).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

SAMPLE_SIZES = [1, 2, 4, 8, 16, 32, 64]
BATCHES = [1, 8]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def psb_input_specs(batch: int):
    specs = [
        jax.ShapeDtypeStruct((batch, M.IMG, M.IMG, 3), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.uint32),
    ]
    for (kn, bias_n) in M.layer_shapes():
        specs += [
            jax.ShapeDtypeStruct(kn, jnp.float32),  # sign
            jax.ShapeDtypeStruct(kn, jnp.float32),  # exp
            jax.ShapeDtypeStruct(kn, jnp.float32),  # prob
            jax.ShapeDtypeStruct((bias_n,), jnp.float32),
        ]
    return specs


def float_input_specs(batch: int):
    specs = [jax.ShapeDtypeStruct((batch, M.IMG, M.IMG, 3), jnp.float32)]
    for (kn, bias_n) in M.layer_shapes():
        specs += [
            jax.ShapeDtypeStruct(kn, jnp.float32),
            jax.ShapeDtypeStruct((bias_n,), jnp.float32),
        ]
    return specs


def make_psb_fn(n: int):
    nlayers = len(M.layer_shapes())

    def fn(x, seed, *flat):
        layers = [
            M.LayerPsb(*flat[4 * i : 4 * i + 4]) for i in range(nlayers)
        ]
        key = jax.random.PRNGKey(seed[0])
        logits, feat = M.forward_psb(layers, x, key, n)
        return (logits, feat)

    return fn


def float_fn(x, *flat):
    nlayers = len(M.layer_shapes())
    params = [M.LayerParams(*flat[2 * i : 2 * i + 2]) for i in range(nlayers)]
    logits, feat = M.forward_float(params, x)
    return (logits, feat)


def emit(out_dir: str, sample_sizes=None, batches=None, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    sample_sizes = sample_sizes or SAMPLE_SIZES
    batches = batches or BATCHES
    meta = {
        "image": M.IMG,
        "num_classes": M.NUM_CLASSES,
        "conv_layers": M.CONV_LAYERS,
        "dense": M.DENSE,
        "layer_shapes": [
            {"weight": list(kn), "bias": bias_n} for kn, bias_n in M.layer_shapes()
        ],
        "q16_scale": 1024,
        "sample_sizes": sample_sizes,
        "batches": batches,
        "psb_inputs": "x, seed(u32[1]), then per layer: sign, exp, prob, bias",
        "float_inputs": "x, then per layer: w, bias",
        "outputs": "(logits[B,10], feat[B,8,8,32])",
        "modules": {},
    }
    for b in batches:
        name = f"float_b{b}"
        lowered = jax.jit(float_fn).lower(*float_input_specs(b))
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, name + ".hlo.txt"), "w") as f:
            f.write(text)
        meta["modules"][name] = {"batch": b, "kind": "float"}
        if verbose:
            print(f"  wrote {name}.hlo.txt ({len(text)} chars)")
        for n in sample_sizes:
            name = f"psb_n{n}_b{b}"
            lowered = jax.jit(make_psb_fn(n)).lower(*psb_input_specs(b))
            text = to_hlo_text(lowered)
            with open(os.path.join(out_dir, name + ".hlo.txt"), "w") as f:
                f.write(text)
            meta["modules"][name] = {"batch": b, "kind": "psb", "n": n}
            if verbose:
                print(f"  wrote {name}.hlo.txt ({len(text)} chars)")
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    # meta.txt: flat whitespace format for the rust loader (the offline
    # rust build has no JSON dependency available).
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        f.write(f"image {M.IMG}\n")
        f.write(f"num_classes {M.NUM_CLASSES}\n")
        f.write("q16_scale 1024\n")
        f.write(f"layers {len(M.layer_shapes())}\n")
        for i, (kn, bias_n) in enumerate(M.layer_shapes()):
            f.write(f"layer {i} {kn[0]} {kn[1]} {bias_n}\n")
        f.write("sample_sizes " + " ".join(str(n) for n in sample_sizes) + "\n")
        f.write("batches " + " ".join(str(b) for b in batches) + "\n")
        for name, info in meta["modules"].items():
            n = info.get("n", "-")
            f.write(f"module {name} {info['kind']} {info['batch']} {n}\n")
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sample-sizes", type=int, nargs="*", default=SAMPLE_SIZES)
    ap.add_argument("--batches", type=int, nargs="*", default=BATCHES)
    args = ap.parse_args()
    emit(args.out_dir, args.sample_sizes, args.batches)
    print(f"artifacts written to {args.out_dir}")


if __name__ == "__main__":
    main()
