"""Pure-jnp oracle for the capacitor-unit matmul (L1 correctness reference).

The capacitor unit (paper Sec. 3.1, Eq. 8/9) multiplies a Q16 fixed-point
activation matrix by stochastically binarized weights and averages the
samples *before* the following non-linearity:

    y = quantize_q16( x @ (s * 2^e * (1 + k/n)) )

with k ~ Binomial(n, p) drawn once per weight.  The Pallas kernel in
``capacitor.py`` must match this reference on the float32 carrier
(same dequantization, same rounding, same saturation).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..psb import quantize_q16


def capacitor_matmul_ref(
    x: jnp.ndarray,
    sign: jnp.ndarray,
    exp: jnp.ndarray,
    counts: jnp.ndarray,
    n: int,
    quantize: bool = True,
) -> jnp.ndarray:
    """Reference capacitor matmul: x[M,K] @ wbar[K,N] with Q16 output.

    ``counts`` are Binomial(n, p) draws, one per weight (Eq. 8); the
    dequantized stochastic weight is wbar = s * 2^e * (1 + k/n).
    """
    wbar = sign * jnp.exp2(exp) * (1.0 + counts / float(n))
    y = x.astype(jnp.float32) @ wbar.astype(jnp.float32)
    return quantize_q16(y) if quantize else y


def capacitor_matmul_mean_ref(
    x: jnp.ndarray,
    sign: jnp.ndarray,
    exp: jnp.ndarray,
    prob: jnp.ndarray,
    quantize: bool = True,
) -> jnp.ndarray:
    """Expectation oracle: uses E[wbar] = s*2^e*(1+p) = w (unbiasedness)."""
    w = sign * jnp.exp2(exp) * (1.0 + prob)
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    return quantize_q16(y) if quantize else y
