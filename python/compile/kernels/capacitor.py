"""L1 Pallas kernel: tiled capacitor-unit matmul with in-tile PSB dequant.

The paper's hot spot is the capacitor unit (Sec. 3.1): every weight is a
stochastic choice between two shifts, accumulated n times and averaged
before the non-linearity.  After folding the n Bernoulli draws into a
Binomial count k (Eq. 8 == rolled-out Eq. 9 after the final ``>> log2 n``),
one inference matmul is

    y[M,N] = quantize_q16( x[M,K] @ (s * 2^e * (1 + k/n))[K,N] )

TPU mapping (DESIGN.md §Hardware-Adaptation): the (s, e, k) triple lives in
VMEM at 2 bytes/weight and is dequantized *inside the tile* right before
the MXU contraction — the HBM->VMEM schedule the paper's ASIC expressed as
its accumulation loop is expressed here with BlockSpec over a (M/bm, N/bn,
K/bk) grid, K innermost, accumulating into the output tile.

Runs under interpret=True on CPU (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..psb import Q16_MAX, Q16_MIN, Q16_SCALE

# TPU deployment tile shapes: MXU-shaped (128 lanes), VMEM-bounded (see
# ``vmem_bytes``).  These are what a real-TPU lowering would use.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128

# CPU-interpret simulation tiles: interpret mode pays a large per-grid-step
# overhead (~0.35 ms/step measured — EXPERIMENTS.md §Perf L1), so the
# simulation default covers each layer in as few tiles as possible.  This
# changes nothing semantically (block-shape invariance is property-tested);
# on TPU the 128³ spec above applies and its VMEM footprint is reported by
# ``vmem_bytes``.
SIM_BLOCK_M = 4096
SIM_BLOCK_N = 256
SIM_BLOCK_K = 512


def _capacitor_kernel(x_ref, s_ref, e_ref, k_ref, o_ref, *, inv_n, nsteps, quantize):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks (innermost)."""
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # In-tile dequantization: wbar = s * 2^e * (1 + k/n). exp2 of the small
    # integer exponent is exact in f32; on TPU this is the VPU prologue that
    # feeds dense bf16 tiles to the MXU.
    wbar = s_ref[...] * jnp.exp2(e_ref[...]) * (1.0 + k_ref[...] * inv_n)
    o_ref[...] += jnp.dot(x_ref[...], wbar, preferred_element_type=jnp.float32)

    if quantize:

        @pl.when(kstep == nsteps - 1)
        def _finalize():
            # Q16 saturation: the capacitor's 16-bit accumulator semantics.
            # Ties round away from zero, bit-compatible with rust f32::round
            # and psb.quantize_q16.
            scaled = o_ref[...] * Q16_SCALE
            q = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
            o_ref[...] = jnp.clip(q, Q16_MIN, Q16_MAX) / Q16_SCALE


def _pad2(a: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, m - a.shape[0]), (0, n - a.shape[1])))


@functools.partial(
    jax.jit, static_argnames=("n", "quantize", "block_m", "block_n", "block_k")
)
def capacitor_matmul(
    x: jnp.ndarray,
    sign: jnp.ndarray,
    exp: jnp.ndarray,
    counts: jnp.ndarray,
    n: int,
    quantize: bool = True,
    block_m: int = SIM_BLOCK_M,
    block_n: int = SIM_BLOCK_N,
    block_k: int = SIM_BLOCK_K,
) -> jnp.ndarray:
    """Capacitor matmul y = q16(x @ wbar) via the tiled Pallas kernel.

    x: [M, K] float32 (Q16-valued activations)
    sign/exp/counts: [K, N] float32 PSB weight planes (k ~ Binomial(n, p))
    n: static sample count (the progressive-precision knob)
    """
    m, k = x.shape
    k2, nn = sign.shape
    assert k == k2, f"contraction mismatch {x.shape} vs {sign.shape}"
    assert exp.shape == (k2, nn) and counts.shape == (k2, nn)

    bm, bn, bk = (min(block_m, m), min(block_n, nn), min(block_k, k))
    mp, np_, kp = (-m % bm + m, -nn % bn + nn, -k % bk + k)
    xp = _pad2(x.astype(jnp.float32), mp, kp)
    sp = _pad2(sign.astype(jnp.float32), kp, np_)
    ep = _pad2(exp.astype(jnp.float32), kp, np_)
    cp = _pad2(counts.astype(jnp.float32), kp, np_)

    nsteps = kp // bk
    grid = (mp // bm, np_ // bn, nsteps)
    out = pl.pallas_call(
        functools.partial(
            _capacitor_kernel, inv_n=1.0 / float(n), nsteps=nsteps, quantize=quantize
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, sp, ep, cp)
    return out[:m, :nn]


def vmem_bytes(block_m: int = BLOCK_M, block_n: int = BLOCK_N, block_k: int = BLOCK_K) -> int:
    """Estimated VMEM footprint of one tile residency (f32 carrier).

    x tile + 3 weight planes + output accumulator. Used by the DESIGN.md
    §Perf roofline estimate (real TPU would hold (e,p) as int8 pairs —
    report both in experiments::table2).
    """
    return 4 * (block_m * block_k + 3 * block_k * block_n + block_m * block_n)
