"""Progressive stochastic binarization (PSB) number system — build-time python side.

Implements the paper's weight re-encoding (Sec. 3.1):

    w  ->  (s, e, p)   with   s = sign(w), e = floor(log2|w|),
                              p = |w| / 2^e - 1  in [0, 1)

    wbar_n = s * 2^e * (B_{n,p} / n + 1)        (Eq. 8)  E[wbar_n] = w

plus the Gumbel-max binomial sampler from the supplementary (Eq. 13-15)
and the 16-bit fixed-point quantizer used for all intermediate results
(range [-32, 32], i.e. Q5.10 with a sign bit).

Everything here is float32-carried simulation, exactly like the paper's
own TensorFlow implementation; the bit-exact integer shift-add semantics
live in the rust `sim::capacitor` module and are cross-checked against
this code by the artifact round-trip tests.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Q16 fixed point: 16-bit two's complement covering [-32, 32)  (Q5.10)
# ---------------------------------------------------------------------------

Q16_SCALE = 1024.0  # 2^10 fractional bits
Q16_MIN = -32768.0
Q16_MAX = 32767.0


def quantize_q16(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize to the paper's 16-bit fixed-point grid in [-32, 32).

    Values are *carried* as float32 (like the paper's TF simulation) but
    restricted to the representable grid: round-to-nearest with ties away
    from zero (matching rust `f32::round`, so the L3 simulator and the
    artifacts agree bit-for-bit — jnp.round would tie-to-even), saturating.
    """
    scaled = x * Q16_SCALE
    q = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    q = jnp.clip(q, Q16_MIN, Q16_MAX)
    return q / Q16_SCALE


# ---------------------------------------------------------------------------
# PSB weight encoding
# ---------------------------------------------------------------------------


class PsbEncoding(NamedTuple):
    """Bijective (sign, exponent, probability) encoding of a weight tensor.

    ``sign`` is -1/0/+1 (0 encodes an exactly-zero weight, e.g. pruned),
    ``exp`` is the integer exponent e = floor(log2 |w|) carried as float32,
    ``prob`` is the mantissa probability p = |w|/2^e - 1 in [0, 1).
    """

    sign: jnp.ndarray
    exp: jnp.ndarray
    prob: jnp.ndarray


def encode(w: jnp.ndarray) -> PsbEncoding:
    """Encode weights into the PSB (s, e, p) representation (Eq. 4-7)."""
    sign = jnp.sign(w)
    aw = jnp.abs(w)
    # Avoid log2(0); sign==0 masks these lanes out entirely.
    safe = jnp.where(aw > 0, aw, 1.0)
    e = jnp.floor(jnp.log2(safe))
    p = safe / jnp.exp2(e) - 1.0
    # Guard numerical round-off: p must live in [0, 1).
    p = jnp.clip(p, 0.0, 1.0 - 1e-7)
    e = jnp.where(sign == 0, 0.0, e)
    p = jnp.where(sign == 0, 0.0, p)
    return PsbEncoding(sign=sign, exp=e, prob=p)


def decode_mean(enc: PsbEncoding) -> jnp.ndarray:
    """Exact expectation of the encoding: E[wbar] = s * 2^e * (1 + p) = w."""
    return enc.sign * jnp.exp2(enc.exp) * (1.0 + enc.prob)


def wbar_from_counts(enc: PsbEncoding, k: jnp.ndarray, n: int) -> jnp.ndarray:
    """Realize the stochastic weight wbar_n = s * 2^e * (1 + k/n)  (Eq. 8).

    ``k`` are Binomial(n, p) counts, carried as float32.
    """
    return enc.sign * jnp.exp2(enc.exp) * (1.0 + k / float(n))


def discretize_prob(p: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize probabilities to ``bits`` bits (Sec. 4.4).

    Regular grid including p=0, excluding p=1 (the right boundary would be
    the next exponent): levels i/2^bits for i in 0..2^bits-1, nearest.
    """
    levels = float(1 << bits)
    return jnp.clip(jnp.round(p * levels), 0.0, levels - 1.0) / levels


# ---------------------------------------------------------------------------
# Binomial sampling via the Gumbel-max trick (supplementary Eq. 13-15)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def sample_binomial_gumbel(key: jax.Array, p: jnp.ndarray, n: int) -> jnp.ndarray:
    """Sample k ~ Binomial(n, p) elementwise with the Gumbel-max trick.

    k = argmax_k [ log C(n,k) + k log p + (n-k) log(1-p) - log(-log U_k) ]

    numerically stabilized with log-rules exactly as the supplementary
    (Eq. 15).  Returns float32 counts with the same shape as ``p``.
    """
    ks = jnp.arange(n + 1, dtype=jnp.float32)
    # log C(n, k) via lgamma — stable for all n we use (n <= 256).
    log_comb = (
        jax.lax.lgamma(jnp.float32(n + 1))
        - jax.lax.lgamma(ks + 1.0)
        - jax.lax.lgamma(jnp.float32(n) - ks + 1.0)
    )
    pf = p.astype(jnp.float32)[..., None]
    eps = 1e-12
    logits = (
        log_comb
        + ks * jnp.log(jnp.maximum(pf, eps))
        + (float(n) - ks) * jnp.log(jnp.maximum(1.0 - pf, eps))
    )
    # p == 0 / p == 1 exact corners: force the degenerate outcome.
    logits = jnp.where(pf == 0.0, jnp.where(ks == 0.0, 0.0, -jnp.inf), logits)
    u = jax.random.uniform(key, logits.shape, minval=eps, maxval=1.0)
    gumbel = -jnp.log(-jnp.log(u))
    return jnp.argmax(logits + gumbel, axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n",))
def sample_binomial_bitsum(key: jax.Array, p: jnp.ndarray, n: int) -> jnp.ndarray:
    """Sample k ~ Binomial(n, p) as the sum of n Bernoulli bits.

    This is *literally* Eq. 9's accumulation semantics (one comparator bit
    per gated add) and, being free of transcendentals, is 3-6x faster than
    the Gumbel-max formulation on CPU (EXPERIMENTS.md §Perf L2). Both
    samplers draw from the identical Binomial(n, p) distribution; the
    Gumbel-max variant is kept as the supplementary-faithful reference.
    """
    u = jax.random.uniform(key, (*p.shape, n))
    return jnp.sum(u < p[..., None], axis=-1).astype(jnp.float32)


def sample_wbar(key: jax.Array, w: jnp.ndarray, n: int) -> jnp.ndarray:
    """Convenience: encode ``w`` and draw one stochastic realization wbar_n."""
    enc = encode(w)
    k = sample_binomial_gumbel(key, enc.prob, n)
    return wbar_from_counts(enc, k, n)
