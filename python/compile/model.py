"""L2: the serving CNN, written in JAX, calling the L1 capacitor kernel.

This is the compute graph the rust coordinator executes at request time.
It is deliberately the same *shape family* as the rust `models::` zoo
(conv -> relu stacks with Q16 intermediates) so that artifact outputs can
be cross-checked against the pure-rust simulator.

Architecture (SAME padding, NHWC):

    q16(x[B,32,32,3])
    conv 3x3 s1  3->16  + bias + relu   (im2col K=27)
    conv 3x3 s2 16->32  + bias + relu   (K=144)
    conv 3x3 s2 32->32  + bias + relu   (K=288)  -> feat [B,8,8,32]
    global mean pool -> dense 32->10 -> logits

Every matmul goes through ``kernels.capacitor.capacitor_matmul`` with the
per-layer PSB planes (sign, exp, prob); Binomial counts are drawn once per
forward with the Gumbel-max sampler (supplementary Eq. 13-15) and shared
across the batch — exactly the paper's "sample the filter directly" setup
(Sec. 4.1).  Weights arrive already BN-folded (folding happens on the rust
side / in `psb`-encoded planes), so the graph itself is BN-free.

Outputs: (logits[B,10], feat[B,8,8,32]).  The feature map feeds the
coordinator's entropy-based precision escalation (paper Sec. 4.5).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.capacitor import capacitor_matmul
from .kernels.ref import capacitor_matmul_ref
from .psb import encode, quantize_q16, sample_binomial_bitsum, sample_binomial_gumbel

# (ksize, stride, cin, cout) per conv layer, then the dense layer.
CONV_LAYERS = [(3, 1, 3, 16), (3, 2, 16, 32), (3, 2, 32, 32)]
DENSE = (32, 10)
IMG = 32
NUM_CLASSES = 10


class LayerParams(NamedTuple):
    w: jnp.ndarray  # [K, N] im2col weight matrix (conv) or [in, out] (dense)
    b: jnp.ndarray  # [N]


class LayerPsb(NamedTuple):
    sign: jnp.ndarray
    exp: jnp.ndarray
    prob: jnp.ndarray
    b: jnp.ndarray


def layer_shapes() -> list[tuple[tuple[int, int], int]]:
    """[(weight [K,N] shape, bias N)] for the 3 convs + dense, in order."""
    shapes = []
    for ks, _s, cin, cout in CONV_LAYERS:
        shapes.append(((ks * ks * cin, cout), cout))
    shapes.append(((DENSE[0], DENSE[1]), DENSE[1]))
    return shapes


def init_params(key: jax.Array) -> list[LayerParams]:
    """LeCun-normal init (the paper's Cifar init), deterministic from key."""
    params = []
    for (kn, bias_n) in layer_shapes():
        key, sub = jax.random.split(key)
        fan_in = kn[0]
        w = jax.random.normal(sub, kn, jnp.float32) / jnp.sqrt(float(fan_in))
        params.append(LayerParams(w=w, b=jnp.zeros((bias_n,), jnp.float32)))
    return params


def encode_params(params: list[LayerParams]) -> list[LayerPsb]:
    """Bijective PSB re-encoding of every layer (no retraining — Sec. 1.1)."""
    out = []
    for p in params:
        enc = encode(p.w)
        out.append(LayerPsb(sign=enc.sign, exp=enc.exp, prob=enc.prob, b=p.b))
    return out


def im2col(x: jnp.ndarray, ksize: int, stride: int) -> jnp.ndarray:
    """SAME-padded patch extraction: [B,H,W,C] -> [B,Ho,Wo,ksize*ksize*C].

    Implemented as ksize^2 shifted strided slices so it lowers to plain
    HLO slices/concats (no gather), which XLA fuses with the following
    reshape+matmul.
    """
    b, h, w, c = x.shape
    pad = ksize // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho, wo = (h + stride - 1) // stride, (w + stride - 1) // stride
    cols = []
    for di in range(ksize):
        for dj in range(ksize):
            patch = xp[:, di : di + h : stride, dj : dj + w : stride, :]
            cols.append(patch[:, :ho, :wo, :])
    return jnp.concatenate(cols, axis=-1)


def _conv_psb(x, layer: LayerPsb, counts, ks, stride, n, use_pallas=True):
    b, h, w, _c = x.shape
    cols = im2col(x, ks, stride)
    ho, wo = cols.shape[1], cols.shape[2]
    flat = cols.reshape(b * ho * wo, cols.shape[3])
    mm = capacitor_matmul if use_pallas else (
        lambda xx, s, e, k, n, quantize=False: capacitor_matmul_ref(xx, s, e, k, n, quantize)
    )
    y = mm(flat, layer.sign, layer.exp, counts, n, quantize=False)
    y = quantize_q16(y + layer.b[None, :])
    return y.reshape(b, ho, wo, -1)


def forward_psb(
    layers: list[LayerPsb],
    x: jnp.ndarray,
    key: jax.Array,
    n: int,
    use_pallas: bool = True,
    sampler: str = "bitsum",
):
    """PSB forward pass at sample size ``n``; returns (logits, feat).

    ``sampler`` picks the Binomial(n, p) draw: "bitsum" (n Bernoulli bits,
    Eq. 9 semantics, fastest on CPU) or "gumbel" (the supplementary's
    Gumbel-max trick).  Both are exact.
    """
    sample = sample_binomial_bitsum if sampler == "bitsum" else sample_binomial_gumbel
    x = quantize_q16(x)
    keys = jax.random.split(key, len(layers))
    feat = None
    for i, (ks, stride, _cin, _cout) in enumerate(CONV_LAYERS):
        counts = sample(keys[i], layers[i].prob, n)
        x = _conv_psb(x, layers[i], counts, ks, stride, n, use_pallas)
        x = jax.nn.relu(x)
        feat = x
    pooled = quantize_q16(jnp.mean(x, axis=(1, 2)))  # [B, 32]
    dlayer = layers[-1]
    counts = sample(keys[-1], dlayer.prob, n)
    mm = capacitor_matmul if use_pallas else (
        lambda xx, s, e, k, nn, quantize=False: capacitor_matmul_ref(xx, s, e, k, nn, quantize)
    )
    logits = mm(pooled, dlayer.sign, dlayer.exp, counts, n, quantize=False)
    logits = quantize_q16(logits + dlayer.b[None, :])
    return logits, feat


def forward_float(params: list[LayerParams], x: jnp.ndarray):
    """float32 baseline of the identical graph (no quantization)."""
    feat = None
    for i, (ks, stride, _cin, _cout) in enumerate(CONV_LAYERS):
        cols = im2col(x, ks, stride)
        b, ho, wo, kdim = cols.shape
        y = cols.reshape(b * ho * wo, kdim) @ params[i].w + params[i].b[None, :]
        x = jax.nn.relu(y).reshape(b, ho, wo, -1)
        feat = x
    pooled = jnp.mean(x, axis=(1, 2))
    logits = pooled @ params[-1].w + params[-1].b[None, :]
    return logits, feat
