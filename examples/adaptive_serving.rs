//! Adaptive-precision serving demo: the L3 coordinator routing a request
//! stream through the PJRT artifacts, comparing flat low-precision, flat
//! high-precision, and entropy-escalated adaptive serving.
//!
//! `make artifacts && cargo run --release --example adaptive_serving`

use psb::coordinator::{Coordinator, CoordinatorConfig, EscalationPolicy};
use psb::data::{Dataset, SynthConfig};
use psb::rng::Xorshift128Plus;
use psb::runtime::{FloatBundle, PsbBundle};
use psb::sim::train::{train, TrainConfig};

const SERVING_SHAPES: [[usize; 2]; 4] = [[27, 16], [144, 32], [288, 32], [32, 10]];
const REQUESTS: usize = 256;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/meta.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    // train the serving model once
    let data = Dataset::synth(&SynthConfig { train: 1536, test: 512, size: 32, seed: 42, ..Default::default() });
    let mut rng = Xorshift128Plus::seed_from(42);
    let mut net = psb::models::serving_cnn(&mut rng);
    eprintln!("training serving CNN...");
    let stats = train(&mut net, &data, &TrainConfig { epochs: 4, ..Default::default() });
    eprintln!("float test acc {:.3}", stats.last().unwrap().test_acc);
    let float = FloatBundle::from_network(&net, &SERVING_SHAPES)?;
    let psb = PsbBundle::from_float(&float, Some(4));

    println!(
        "{:>12} {:>9} {:>9} {:>10} {:>9} {:>10} {:>12}",
        "mode", "req/s", "acc", "p50", "p99", "escal.", "adds/req"
    );
    for (name, policy) in [
        ("flat psb8", EscalationPolicy { n_low: 8, n_high: 16, disabled: true, ..Default::default() }),
        ("flat psb16", EscalationPolicy { n_low: 16, n_high: 16, disabled: true, ..Default::default() }),
        ("adaptive", EscalationPolicy { n_low: 8, n_high: 16, ..Default::default() }),
    ] {
        let cfg = CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            policy,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg, psb.clone(), float.clone())?;
        let start = std::time::Instant::now();
        let mut inflight = Vec::with_capacity(REQUESTS);
        for i in 0..REQUESTS {
            let (x, labels) = data.gather_test(&[i % data.test_images.shape[0]]);
            inflight.push((labels[0], coord.submit(x.data)?));
        }
        let mut correct = 0usize;
        for (label, rx) in &inflight {
            let resp = rx.recv()?;
            correct += (resp.class == *label) as usize;
        }
        let elapsed = start.elapsed();
        let m = &coord.metrics;
        println!(
            "{:>12} {:>9.0} {:>9.3} {:>10.1?} {:>9.1?} {:>9.1}% {:>12.2e}",
            name,
            REQUESTS as f64 / elapsed.as_secs_f64(),
            correct as f64 / REQUESTS as f64,
            m.latency.quantile(0.5),
            m.latency.quantile(0.99),
            100.0 * m.escalation_rate(),
            m.gated_adds.load(std::sync::atomic::Ordering::Relaxed) as f64 / REQUESTS as f64,
        );
    }
    println!("\nadaptive should sit between the flat modes in adds/req while tracking\nflat-psb16 accuracy — the serving-level version of the paper's Sec. 4.5.");
    Ok(())
}
