//! Adaptive-precision serving demo: the L3 coordinator routing a request
//! stream, comparing flat low-precision, flat high-precision, and
//! entropy-escalated adaptive serving.
//!
//! With AOT artifacts present (`make artifacts`) the PJRT engine serves;
//! without them the pure-rust simulator engine serves instead — slower,
//! but escalations then *genuinely* refine the stage-1 capacitor state
//! (progressive refinement), visible in the reuse column.
//!
//! `cargo run --release --example adaptive_serving`

use psb::coordinator::{Coordinator, CoordinatorConfig, EscalationPolicy};
use psb::data::{Dataset, SynthConfig};
use psb::rng::Xorshift128Plus;
use psb::runtime::{FloatBundle, PsbBundle};
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::train::{train, TrainConfig};

const SERVING_SHAPES: [[usize; 2]; 4] = [[27, 16], [144, 32], [288, 32], [32, 10]];

fn main() -> anyhow::Result<()> {
    // PSB_QUICK=1 shrinks the run for CI smoke jobs
    let quick = std::env::var("PSB_QUICK").is_ok();
    // the PJRT path needs the artifacts AND the pjrt cargo feature
    let have_artifacts =
        cfg!(feature = "pjrt") && std::path::Path::new("artifacts/meta.txt").exists();
    let requests: usize = if have_artifacts { 256 } else if quick { 24 } else { 64 };
    // train the serving model once
    let n_train = if quick { 512 } else { 1536 };
    let data = Dataset::synth(&SynthConfig { train: n_train, test: 512, size: 32, seed: 42, ..Default::default() });
    let mut rng = Xorshift128Plus::seed_from(42);
    let mut net = psb::models::serving_cnn(&mut rng);
    eprintln!("training serving CNN...");
    let epochs = if quick { 1 } else { 4 };
    let stats = train(&mut net, &data, &TrainConfig { epochs, ..Default::default() });
    eprintln!("float test acc {:.3}", stats.last().unwrap().test_acc);
    let float = FloatBundle::from_network(&net, &SERVING_SHAPES)?;
    let psb = PsbBundle::from_float(&float, Some(4));
    // capacitor re-encoding is only needed for the simulator engine
    let psb_net = (!have_artifacts).then(|| {
        eprintln!("PJRT unavailable — serving through the simulator engine");
        PsbNetwork::prepare(&net, PsbOptions::default())
    });

    println!(
        "{:>12} {:>9} {:>9} {:>10} {:>9} {:>10} {:>10} {:>12}",
        "mode", "req/s", "acc", "p50", "p99", "escal.", "reuse", "adds/req"
    );
    for (name, policy) in [
        ("flat psb8", EscalationPolicy { n_low: 8, n_high: 16, disabled: true, ..Default::default() }),
        ("flat psb16", EscalationPolicy { n_low: 16, n_high: 16, disabled: true, ..Default::default() }),
        ("adaptive", EscalationPolicy { n_low: 8, n_high: 16, ..Default::default() }),
    ] {
        let cfg = CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            policy,
            ..Default::default()
        };
        let coord = match &psb_net {
            None => Coordinator::start(cfg, psb.clone())?,
            Some(net) => Coordinator::start_sim(cfg, net.clone())?,
        };
        let start = std::time::Instant::now();
        let mut inflight = Vec::with_capacity(requests);
        for i in 0..requests {
            let (x, labels) = data.gather_test(&[i % data.test_images.shape[0]]);
            inflight.push((labels[0], coord.submit(x.data)?));
        }
        let mut correct = 0usize;
        for (label, rx) in &inflight {
            let resp = rx.recv()??;
            correct += (resp.class == *label) as usize;
        }
        let elapsed = start.elapsed();
        let m = &coord.metrics;
        println!(
            "{:>12} {:>9.0} {:>9.3} {:>10.1?} {:>9.1?} {:>9.1}% {:>9.1}% {:>12.2e}",
            name,
            requests as f64 / elapsed.as_secs_f64(),
            correct as f64 / requests as f64,
            m.latency.quantile(0.5),
            m.latency.quantile(0.99),
            100.0 * m.escalation_rate(),
            100.0 * m.reuse_ratio(),
            m.gated_adds.load(std::sync::atomic::Ordering::Relaxed) as f64 / requests as f64,
        );
    }
    println!("\nadaptive should sit between the flat modes in adds/req while tracking\nflat-psb16 accuracy — the serving-level version of the paper's Sec. 4.5;\nthe reuse column is the sample fraction progressive refinement avoided.");
    Ok(())
}
