//! End-to-end system validation: all three layers composing on a real
//! (synthetic-data) workload.  `cargo run --release --example end_to_end`
//!
//! 1. **Train** the serving CNN in the rust simulator (loss curve logged).
//! 2. **Fold + encode** its weights into PSB planes (bijective, no
//!    retraining) — the exact input signature of the AOT artifacts.
//! 3. **Cross-check L3 vs L2/L1**: run the same images through (a) the
//!    pure-rust simulator and (b) the JAX/Pallas-lowered PJRT artifacts;
//!    float paths must agree to Q16 tolerance, PSB paths statistically.
//! 4. **Reproduce the headline**: accuracy vs sample size + the two-stage
//!    attention saving, printed as Table-1-style rows.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use psb::attention::adaptive_forward;
use psb::backend::SimBackend;
use psb::data::{Dataset, SynthConfig};
use psb::rng::Xorshift128Plus;
use psb::runtime::{FloatBundle, PsbBundle, Runtime};
use psb::sim::layers::argmax_rows;
use psb::precision::PrecisionPlan;
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::train::{evaluate_psb, train, TrainConfig};

const SERVING_SHAPES: [[usize; 2]; 4] = [[27, 16], [144, 32], [288, 32], [32, 10]];

fn main() -> anyhow::Result<()> {
    // ---------- 1. train ------------------------------------------------------
    let data = Dataset::synth(&SynthConfig {
        train: 2048,
        test: 512,
        size: 32,
        seed: 42,
        ..Default::default()
    });
    let mut rng = Xorshift128Plus::seed_from(42);
    let mut net = psb::models::serving_cnn(&mut rng);
    println!("=== 1. training serving CNN ({} params) ===", net.num_params());
    let stats = train(&mut net, &data, &TrainConfig { epochs: 6, verbose: true, ..Default::default() });
    let float_acc = stats.last().unwrap().test_acc;
    println!("loss curve: {:?}", stats.iter().map(|s| (s.epoch, s.loss)).collect::<Vec<_>>());
    println!("float32 test accuracy: {float_acc:.3}");

    // ---------- 2. fold + encode ----------------------------------------------
    println!("\n=== 2. BN folding + bijective PSB encoding ===");
    let float_bundle = FloatBundle::from_network(&net, &SERVING_SHAPES)?;
    let psb_bundle = PsbBundle::from_float(&float_bundle, None);
    for (i, l) in psb_bundle.layers.iter().enumerate() {
        let dec = psb_bundle.decode_layer(i);
        let max_err = dec
            .iter()
            .zip(&float_bundle.layers[i].w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("  layer {i} {:?}: round-trip max err {max_err:.2e}", l.shape);
    }

    // ---------- 3. cross-check sim vs PJRT artifacts ---------------------------
    println!("\n=== 3. L3 sim vs L2/L1 artifacts (PJRT) ===");
    let artifact_dir = std::path::Path::new("artifacts");
    if cfg!(feature = "pjrt") && artifact_dir.join("meta.txt").exists() {
        let mut rt = Runtime::new(artifact_dir)?;
        let (x, labels) = data.gather_test(&(0..8).collect::<Vec<_>>());
        // float path: must agree to numerical tolerance
        let exec = rt.run_float(8, &x.data, &float_bundle)?;
        let sim = net.forward::<Xorshift128Plus>(&x, false, None);
        let sim_logits = &sim.logits().data;
        let max_err = exec
            .logits
            .iter()
            .zip(sim_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("  float32: max |PJRT − sim| over logits = {max_err:.2e}");
        anyhow::ensure!(max_err < 1e-2, "float paths disagree");
        // psb path: same argmax on most rows at n=64
        let psb_exec = rt.run_psb(64, 8, &x.data, 7, &psb_bundle)?;
        let a1 = argmax_rows(&psb_exec.logits, 10);
        let a2 = argmax_rows(sim_logits, 10);
        let agree = a1.iter().zip(&a2).filter(|(p, q)| p == q).count();
        println!("  psb64 (PJRT) vs float (sim): argmax agreement {agree}/8 (labels {labels:?})");
        println!("  compiled modules: {:?}", rt.loaded_modules());
    } else {
        println!("  [skipped: run `make artifacts` first]");
    }

    // ---------- 4. headline table ----------------------------------------------
    println!("\n=== 4. accuracy vs sample size + attention (paper headline) ===");
    let psb = SimBackend::new(PsbNetwork::prepare(&net, PsbOptions::default()));
    println!("{:>14} {:>10} {:>10} {:>16}", "system", "top-1", "rel.", "gated adds");
    println!("{:>14} {:>10.3} {:>9.1}% {:>16}", "float32", float_acc, 100.0, "-");
    let mut psb16_adds = 0u64;
    for n in [4u32, 8, 16, 32, 64] {
        let (acc, costs) = evaluate_psb(&psb, &data, &PrecisionPlan::uniform(n), 11);
        if n == 16 {
            psb16_adds = costs.gated_adds;
        }
        println!(
            "{:>14} {acc:>10.3} {:>9.1}% {:>16}",
            format!("psb{n}"),
            100.0 * acc / float_acc,
            costs.gated_adds
        );
    }
    // attention psb8/16 over the test set
    let n_imgs = data.test_images.shape[0];
    let mut correct = 0usize;
    let mut adds = 0u64;
    let mut frac = 0.0f64;
    let mut batches = 0;
    for start in (0..n_imgs).step_by(64) {
        let idx: Vec<usize> = (start..(start + 64).min(n_imgs)).collect();
        let (x, labels) = data.gather_test(&idx);
        let out = adaptive_forward(&psb, &x, 8, 16, 13 + start as u64);
        let preds = argmax_rows(&out.logits.data, 10);
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        adds += out.costs.gated_adds;
        frac += out.interesting_fraction as f64;
        batches += 1;
    }
    let acc = correct as f32 / n_imgs as f32;
    let saving = 100.0 * (1.0 - adds as f64 / psb16_adds as f64);
    println!(
        "{:>14} {acc:>10.3} {:>9.1}% {adds:>16}   <- {saving:.0}% below flat psb16 (interesting {:.0}%)",
        "psb8/16 attn",
        100.0 * acc / float_acc,
        100.0 * frac / batches as f64
    );
    println!("\nend_to_end OK");
    Ok(())
}
