//! Hardware cost explorer: the supplementary Table-2 model applied to a
//! single capacitor unit and to full networks, plus the break-even
//! analysis that motivates PSB's "progressive" knob.
//!
//! `cargo run --release --example hardware_costs`

use psb::costs::{break_even_n, table2, CostCounter};

fn main() {
    println!("45nm unit costs (paper supplementary Table 2):");
    println!("{:>10} {:>12} {:>10}", "op", "area[um2]", "energy[pJ]");
    for (name, c) in table2::ROWS {
        println!("{name:>10} {:>12.0} {:>10.2}", c.area_um2, c.energy_pj);
    }

    let fp32_mac = table2::FP32_MUL.energy_pj + table2::FP32_ADD.energy_pj;
    let int8_mac = table2::INT8_MUL.energy_pj + table2::INT32_ADD.energy_pj;
    let psb_sample = table2::INT16_ADD.energy_pj + table2::INT8_ADD.energy_pj;
    println!("\nper-MAC energy:");
    println!("  fp32 MAC             : {fp32_mac:.2} pJ");
    println!("  int8 MAC (JACOB [31]): {int8_mac:.2} pJ");
    println!("  PSB sample (int16 add + comparator bit): {psb_sample:.2} pJ");
    println!("\nbreak-even sample sizes (PSB cheaper below):");
    println!("  vs fp32 MAC: n <= {}", break_even_n(fp32_mac));
    println!("  vs int8 MAC: n <= {}", break_even_n(int8_mac));

    println!("\nenergy for one 2.2M-MAC serving-CNN inference by sample size:");
    println!("{:>8} {:>14} {:>12}", "n", "energy [uJ]", "vs fp32");
    let macs = 2_211_160u64;
    let mut base = CostCounter::default();
    base.charge_capacitor(macs, 1);
    let fp32 = base.fp32_energy_pj();
    for n in [1u32, 2, 4, 8, 16, 32, 64, 128] {
        let mut c = CostCounter::default();
        c.charge_capacitor(macs, n);
        println!(
            "{n:>8} {:>14.2} {:>11.2}x",
            c.psb_energy_pj() / 1e6,
            fp32 / c.psb_energy_pj()
        );
    }
    println!(
        "\nthe progressive knob: the same weights serve any row of this table at\nrun time — the paper's attention mechanism picks the row per region."
    );
}
