//! Temporal delta streaming demo: a fixed-camera frame stream served
//! through the coordinator's `submit_frame` path, where each frame is
//! an O(Δ) *rebase* of one pinned pooled session instead of a fresh
//! begin — with per-frame fork-escalation when the entropy signal asks
//! for it.
//!
//! Frames drift: a band of pixel rows sweeps down the image over time
//! while the rest of the scene stays fixed, so consecutive frames agree
//! almost everywhere.  The closing metrics line shows how much of each
//! frame actually changed (`mean_frac`) and how many input elements the
//! backend got to reuse.
//!
//! `cargo run --release --example stream_inference`  (PSB_QUICK=1 shrinks it)

use psb::coordinator::{Coordinator, CoordinatorConfig, EscalationPolicy};
use psb::data::{Dataset, SynthConfig};
use psb::rng::Xorshift128Plus;
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::train::{train, TrainConfig};

const STREAM: u64 = 1;

fn main() -> anyhow::Result<()> {
    // PSB_QUICK=1 shrinks the run for CI smoke jobs
    let quick = std::env::var("PSB_QUICK").is_ok();
    let size = 32usize;
    let n_train = if quick { 512 } else { 1536 };
    let data = Dataset::synth(&SynthConfig {
        train: n_train,
        test: 64,
        size,
        seed: 42,
        ..Default::default()
    });
    let mut rng = Xorshift128Plus::seed_from(42);
    let mut net = psb::models::serving_cnn(&mut rng);
    eprintln!("training serving CNN...");
    let epochs = if quick { 1 } else { 3 };
    train(&mut net, &data, &TrainConfig { epochs, ..Default::default() });
    let psb_net = PsbNetwork::prepare(&net, PsbOptions::default());

    let cfg = CoordinatorConfig {
        policy: EscalationPolicy { n_low: 8, n_high: 16, ..Default::default() },
        ..Default::default()
    };
    let coord = Coordinator::start_sim(cfg, psb_net)?;

    // a fixed scene + a foreign band of rows sweeping down it over time
    let (scene, _) = data.gather_test(&[0]);
    let (intruder, _) = data.gather_test(&[1]);
    let row = size * 3; // one pixel row, all channels
    let band_rows = 3usize;
    let frames = if quick { 8 } else { 24 };

    println!("{:>6} {:>7} {:>11} {:>8} {:>9} {:>10}", "frame", "class", "confidence", "n_used", "escal.", "served");
    for t in 0..frames {
        let mut frame = scene.data.clone();
        let top = (t * 2) % (size - band_rows);
        frame[top * row..(top + band_rows) * row]
            .copy_from_slice(&intruder.data[top * row..(top + band_rows) * row]);
        let resp = coord.submit_frame(STREAM, frame)?;
        println!(
            "{t:>6} {:>7} {:>11.3} {:>8} {:>9} {:>10?}",
            resp.class, resp.confidence, resp.n_used, resp.escalated, resp.served
        );
    }

    let m = &coord.metrics;
    println!(
        "\n{} of {frames} frames served by O(Δ) rebase (the first opens the stream); \
         mean changed fraction {:.3}, {} unchanged input elements reused.",
        m.stream_frames.load(std::sync::atomic::Ordering::Relaxed),
        m.stream_mean_frac(),
        m.stream_rows_reused.load(std::sync::atomic::Ordering::Relaxed),
    );
    println!("{}", m.summary());
    coord.close_stream(STREAM)?;
    Ok(())
}
