//! Quickstart: the PSB number system and in-place binarization in ~60
//! lines.  Run with `cargo run --release --example quickstart`.
//!
//! 1. encode a weight into (sign, exponent, probability);
//! 2. train a tiny CNN on the synthetic dataset (float32);
//! 3. binarize it *in place* (no retraining) and watch accuracy converge
//!    to the float baseline as the sample size n grows — the paper's
//!    core claim.

use psb::backend::SimBackend;
use psb::data::{Dataset, SynthConfig};
use psb::num::PsbWeight;
use psb::rng::Xorshift128Plus;
use psb::precision::PrecisionPlan;
use psb::sim::psbnet::{PsbNetwork, PsbOptions};
use psb::sim::train::{evaluate, evaluate_psb, train, TrainConfig};

fn main() -> anyhow::Result<()> {
    // PSB_QUICK=1 shrinks the run for CI smoke jobs
    let quick = std::env::var("PSB_QUICK").is_ok();
    // --- 1. the number system -------------------------------------------------
    let w = 0.37f32;
    let enc = PsbWeight::encode(w);
    println!("PSB encoding of w = {w}:");
    println!("  sign = {}, e = {} (2^e = {}), p = {:.4}", enc.sign, enc.exp, (enc.exp as f32).exp2(), enc.prob);
    println!("  E[wbar] = {} (bijective: decodes back exactly)", enc.decode());
    let mut rng = Xorshift128Plus::seed_from(1);
    let draws: Vec<f32> = (0..8).map(|_| enc.sample_single(&mut rng)).collect();
    println!("  single-sample draws (one random bit -> one of two shifts): {draws:?}");

    // --- 2. train a small float model -----------------------------------------
    let (n_train, n_test) = if quick { (256, 128) } else { (1024, 512) };
    let data = Dataset::synth(&SynthConfig { train: n_train, test: n_test, size: 32, seed: 7, ..Default::default() });
    let mut rng = Xorshift128Plus::seed_from(2);
    let mut net = psb::models::cnn8(32, &mut rng);
    println!("\ntraining cnn8 ({} params) on SynthImages...", net.num_params());
    let cfg = TrainConfig { epochs: if quick { 1 } else { 3 }, verbose: true, ..Default::default() };
    train(&mut net, &data, &cfg);
    let float_acc = evaluate(&mut net, &data);
    println!("float32 test accuracy: {float_acc:.3}");

    // --- 3. in-place binarization: accuracy vs sample size --------------------
    // execution goes through a backend session: open a plan, run, read
    // the logits + hardware charge from the session's cost report
    let backend = SimBackend::new(PsbNetwork::prepare(&net, PsbOptions::default()));
    println!("\nPSB inference (no retraining — weights re-encoded bijectively):");
    println!("{:>6} {:>10} {:>12} {:>14}", "n", "accuracy", "rel. acc", "gated adds");
    let sweep: &[u32] = if quick { &[1, 8, 64] } else { &[1, 2, 4, 8, 16, 32, 64] };
    for &n in sweep {
        let (acc, costs) = evaluate_psb(&backend, &data, &PrecisionPlan::uniform(n), 3);
        println!(
            "{n:>6} {acc:>10.3} {:>11.1}% {:>14}",
            100.0 * acc / float_acc,
            costs.gated_adds
        );
    }
    println!("\naccuracy converges to the float line as n grows — paper Fig. 3.");

    // --- 4. (optional) the integer kernel's direct-conv strategy --------------
    // PSB_DIRECT_CONV=1 runs one batch through the IntKernel twice — the
    // im2col-free direct convolution walk forced on, then off — and checks
    // logits and executed adds are identical: the walk is an execution-
    // order strategy, never a numerics change.
    if std::env::var("PSB_DIRECT_CONV").is_ok() {
        use psb::backend::intkernel::{DirectConv, IntKernelConfig};
        use psb::backend::{Backend, InferenceSession as _, IntKernel};
        use psb::rng::Rng as _;
        use psb::sim::tensor::Tensor;
        let psbnet = PsbNetwork::prepare(&net, PsbOptions::default());
        let mut rng = Xorshift128Plus::seed_from(3);
        let x = Tensor::from_vec(
            (0..2 * 32 * 32 * 3).map(|_| rng.uniform()).collect(),
            &[2, 32, 32, 3],
        );
        let run = |dc: DirectConv| -> anyhow::Result<(Vec<f32>, u64, &'static str)> {
            let kernel = IntKernel::new(psbnet.clone())?
                .with_config(IntKernelConfig { direct_conv: dc, ..Default::default() });
            let mut sess = kernel.open(&PrecisionPlan::uniform(8))?;
            let step = sess.begin(&x, 11)?;
            Ok((sess.logits().data.clone(), step.executed_adds, step.kernel_path.as_str()))
        };
        let (direct, direct_adds, direct_path) = run(DirectConv::Always)?;
        let (cached, cached_adds, cached_path) = run(DirectConv::Never)?;
        anyhow::ensure!(
            direct == cached && direct_adds == cached_adds,
            "direct-conv walk must be bit-identical to the cached lowering"
        );
        println!(
            "\ndirect-conv check: {direct_path} pass ≡ {cached_path} pass \
             ({direct_adds} executed adds) — bit-identical"
        );
    }
    Ok(())
}
